//! Processor-group communicators: split the p-processor machine into
//! disjoint groups that superstep independently.
//!
//! A [`Communicator`] partitions `0..p` into groups, each with
//! group-scoped ranks, its own barrier, and a group-scoped view of the
//! engine's p×p slot matrix.  [`Communicator::enter`] wraps a
//! [`BspCtx`] into a [`GroupCtx`] — an implementation of
//! [`BspScope`] whose `pid`/`nprocs`/`send`/`sync` all operate on the
//! sub-machine — so the one-level sorting algorithms run *group-locally
//! without any new threads or data movement machinery* (the mechanism
//! behind `sort::multilevel`, after "Practical/Robust Massively Parallel
//! Sorting"'s recursion over processor groups).
//!
//! ## The group communication discipline
//!
//! Between entering a group scope and the scope's last `sync`, a
//! processor must communicate only *within its group* (automatic when
//! all sends go through [`GroupCtx`]: destinations are group ranks).  A
//! group `sync` waits only on the group's own barrier and drains only
//! the slots written by group members, which is what makes a stalled or
//! slow group unable to block its siblings — and what makes cross-group
//! sends during a group superstep a data race on the slot matrix.
//! Whole-machine syncs may resume once every group has left its scope
//! (in SPMD terms: after the group phase, the program returns to
//! ordinary `ctx.sync` calls).
//!
//! Ledger accounting: group supersteps are recorded per
//! `(communicator id, group superstep, leader)` — the superstep index
//! comes from a per-group counter owned by the communicator (advanced
//! by each sync's barrier leader), so records stay correct even when
//! sibling groups run different superstep counts and the threads are
//! later regrouped.  Records carry their participant count, are priced
//! with the group-local effective machine (`BspParams::scaled_to`), and
//! max-reduce across concurrent sibling groups — see
//! [`crate::bsp::ledger::SuperstepRecord`].

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Barrier;

use crate::key::Key;

use super::engine::{BspCtx, BspScope, GroupScope};
use super::msg::Payload;

/// Process-wide communicator id source: every [`Communicator`] (and
/// every `bsp::sim::SimCommunicator`) gets a distinct id so the ledger
/// can key group records by `(communicator, group step, leader)` — a
/// program that uses several communicators in sequence (even with
/// diverging per-group superstep counts in between) never merges
/// unrelated groups' records.
static NEXT_COMM_ID: AtomicUsize = AtomicUsize::new(0);

/// Draw a fresh process-unique communicator id (shared counter with the
/// simulator backend's communicators).
pub(super) fn next_comm_id() -> usize {
    NEXT_COMM_ID.fetch_add(1, Ordering::Relaxed)
}

/// The backend-independent part of a communicator: a validated partition
/// of `0..p` into disjoint, ascending member lists, with pid → group and
/// pid → rank indices.
///
/// [`Communicator`] (threaded engine) adds per-group barriers and
/// superstep counters on top; `bsp::sim::SimCommunicator` (deterministic
/// simulator) needs only the partition itself.
pub struct GroupMap {
    /// Global pids per group, each sorted ascending.
    groups: Vec<Vec<usize>>,
    /// pid → group index.
    group_of: Vec<usize>,
    /// pid → rank within its group.
    rank_of: Vec<usize>,
}

impl GroupMap {
    /// Split `p` processors into `num_groups` contiguous blocks of
    /// near-equal size (the first `p % num_groups` groups take one
    /// extra processor).  Contiguous blocks keep pid order consistent
    /// with group order, so a sort that routes ascending key ranges to
    /// ascending groups stays globally sorted in pid order.
    pub fn split_even(p: usize, num_groups: usize) -> GroupMap {
        assert!(num_groups >= 1, "need at least one group");
        assert!(num_groups <= p, "cannot split {p} processors into {num_groups} groups");
        let base = p / num_groups;
        let extra = p % num_groups;
        let mut groups = Vec::with_capacity(num_groups);
        let mut next = 0usize;
        for gidx in 0..num_groups {
            let size = base + usize::from(gidx < extra);
            groups.push((next..next + size).collect());
            next += size;
        }
        GroupMap::from_groups(groups)
    }

    /// Build a partition from explicit member lists.  The lists must be
    /// non-empty, sorted ascending, and together form a disjoint cover
    /// of `0..p` where `p` is the total member count.
    pub fn from_groups(groups: Vec<Vec<usize>>) -> GroupMap {
        let p: usize = groups.iter().map(|g| g.len()).sum();
        assert!(p > 0, "communicator must cover at least one processor");
        let mut group_of = vec![usize::MAX; p];
        let mut rank_of = vec![usize::MAX; p];
        for (gidx, members) in groups.iter().enumerate() {
            assert!(!members.is_empty(), "group {gidx} is empty");
            assert!(
                members.windows(2).all(|w| w[0] < w[1]),
                "group {gidx} members must be sorted ascending and distinct"
            );
            for (rank, &pid) in members.iter().enumerate() {
                assert!(pid < p, "pid {pid} out of range for {p} processors");
                assert_eq!(
                    group_of[pid],
                    usize::MAX,
                    "pid {pid} appears in more than one group"
                );
                group_of[pid] = gidx;
                rank_of[pid] = rank;
            }
        }
        GroupMap { groups, group_of, rank_of }
    }

    /// Total processors covered by the partition.
    pub fn nprocs(&self) -> usize {
        self.group_of.len()
    }

    /// Number of groups.
    pub fn num_groups(&self) -> usize {
        self.groups.len()
    }

    /// Global pids of `group`, sorted ascending (rank order).
    pub fn members(&self, group: usize) -> &[usize] {
        &self.groups[group]
    }

    /// Size of `group`.
    pub fn group_size(&self, group: usize) -> usize {
        self.groups[group].len()
    }

    /// The group index of global `pid`.
    pub fn group_of(&self, pid: usize) -> usize {
        self.group_of[pid]
    }

    /// `pid`'s rank within its group.
    pub fn rank_of(&self, pid: usize) -> usize {
        self.rank_of[pid]
    }

    /// Refine the partition: split every group into `factor` contiguous
    /// near-even sub-groups (the first `len % factor` sub-groups take
    /// one extra member).  Sub-groups keep their parent's member order,
    /// and the sub-groups of group `c` occupy the index range
    /// `c*factor .. (c+1)*factor` of the refined map — the alignment the
    /// multi-level sorts rely on to route ascending key ranges to
    /// ascending sub-groups.
    pub fn refine(&self, factor: usize) -> GroupMap {
        assert!(factor >= 1, "refinement factor must be at least 1");
        let mut groups = Vec::with_capacity(self.num_groups() * factor);
        for (gidx, members) in self.groups.iter().enumerate() {
            assert!(
                factor <= members.len(),
                "cannot refine group {gidx} of {} processors into {factor} sub-groups",
                members.len()
            );
            let base = members.len() / factor;
            let extra = members.len() % factor;
            let mut next = 0usize;
            for sub in 0..factor {
                let size = base + usize::from(sub < extra);
                groups.push(members[next..next + size].to_vec());
                next += size;
            }
        }
        GroupMap::from_groups(groups)
    }
}

/// Maximum depth of a [`Topology`]: with every factor ≥ 2 this covers
/// machines up to 2^16 processors, and it keeps the type `Copy` (it
/// rides `experiment::RunSpec`, which is copied freely).
pub const MAX_TOPOLOGY_DEPTH: usize = 16;

/// A processor-group topology tree `p = k1 × k2 × … × kd`, flattened to
/// its factor vector.
///
/// Depth 1 (`[p]`) is the one-level sort on the whole machine; depth `d`
/// splits the machine into `k1` groups, each group into `k2` sub-groups,
/// and so on, with the leaf sort running on `kd`-processor machines.
/// [`Topology::communicators`] materializes the `d − 1` routing levels
/// as a refinement chain of backend communicators over *global* pids
/// (level `ℓ` refines level `ℓ − 1`), which is what lets the recursive
/// sorts enter each level from the root scope without nested borrows.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct Topology {
    len: u8,
    factors: [u16; MAX_TOPOLOGY_DEPTH],
}

impl Topology {
    /// Build a topology from its factor vector (`[8, 4, 4]` reads "split
    /// into 8 groups, each into 4, leaf machines of 4").
    pub fn new(factors: &[usize]) -> Topology {
        assert!(
            !factors.is_empty() && factors.len() <= MAX_TOPOLOGY_DEPTH,
            "topology depth must be 1..={MAX_TOPOLOGY_DEPTH}, got {}",
            factors.len()
        );
        let mut packed = [0u16; MAX_TOPOLOGY_DEPTH];
        for (i, &k) in factors.iter().enumerate() {
            assert!(k >= 1, "topology factor {i} must be at least 1");
            assert!(k <= u16::MAX as usize, "topology factor {k} too large");
            packed[i] = k as u16;
        }
        Topology { len: factors.len() as u8, factors: packed }
    }

    /// The depth-1 topology: the one-level sort across all `p`
    /// processors.
    pub fn flat(p: usize) -> Topology {
        Topology::new(&[p])
    }

    /// The depth-2 topology `[k, p/k]` the two-level sorts use (`k` must
    /// divide `p`).
    pub fn two_level(p: usize, k: usize) -> Topology {
        assert!(k >= 1 && p % k == 0, "{k} groups must divide p={p}");
        Topology::new(&[k, p / k])
    }

    /// Number of levels `d` (1 = one-level sort).
    pub fn depth(&self) -> usize {
        self.len as usize
    }

    /// The factor `k_{level+1}` (0-indexed).
    pub fn factor(&self, level: usize) -> usize {
        assert!(level < self.depth());
        self.factors[level] as usize
    }

    /// The factor vector as a plain slice-backed `Vec`.
    pub fn dims(&self) -> Vec<usize> {
        (0..self.depth()).map(|i| self.factor(i)).collect()
    }

    /// Total processors `k1·k2·…·kd`.
    pub fn nprocs(&self) -> usize {
        (0..self.depth()).map(|i| self.factor(i)).product()
    }

    /// Render as `"8x4x4"` (the CLI / report notation).
    pub fn label(&self) -> String {
        self.dims().iter().map(|k| k.to_string()).collect::<Vec<_>>().join("x")
    }

    /// Materialize the `d − 1` routing-level communicators as a
    /// refinement chain over global pids: `comms[0]` splits the machine
    /// into `k1` groups, `comms[ℓ]` refines `comms[ℓ−1]` by `k_{ℓ+1}`.
    /// The leaf machines are the cells of the *last* communicator.
    pub fn communicators<C: GroupPartition>(&self) -> Vec<C> {
        let d = self.depth();
        if d <= 1 {
            return Vec::new();
        }
        let mut maps: Vec<GroupMap> = Vec::with_capacity(d - 1);
        maps.push(GroupMap::split_even(self.nprocs(), self.factor(0)));
        for level in 1..d - 1 {
            let refined = maps[level - 1].refine(self.factor(level));
            maps.push(refined);
        }
        maps.into_iter().map(C::from_map).collect()
    }
}

impl std::fmt::Display for Topology {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.label())
    }
}

/// The partition interface the multi-level sorts are generic over: any
/// backend's communicator exposes its [`GroupMap`], and the accessors
/// below are provided from it.  Implemented by [`Communicator`]
/// (threaded engine) and `bsp::sim::SimCommunicator` (deterministic
/// simulator), so `sort::multilevel` runs unmodified on either backend.
pub trait GroupPartition {
    /// Build the contiguous near-even partition (see
    /// [`GroupMap::split_even`]) as this backend's communicator.
    fn split_even(p: usize, num_groups: usize) -> Self
    where
        Self: Sized;

    /// Wrap a validated partition as this backend's communicator (the
    /// hook [`Topology::communicators`] builds refinement chains with).
    fn from_map(map: GroupMap) -> Self
    where
        Self: Sized;

    /// The underlying partition.
    fn map(&self) -> &GroupMap;

    /// Total processors covered by the partition.
    fn nprocs(&self) -> usize {
        self.map().nprocs()
    }

    /// Number of groups.
    fn num_groups(&self) -> usize {
        self.map().num_groups()
    }

    /// Global pids of `group`, sorted ascending (rank order).
    fn members(&self, group: usize) -> &[usize] {
        self.map().members(group)
    }

    /// Size of `group`.
    fn group_size(&self, group: usize) -> usize {
        self.map().group_size(group)
    }

    /// The group index of global `pid`.
    fn group_of(&self, pid: usize) -> usize {
        self.map().group_of(pid)
    }

    /// `pid`'s rank within its group.
    fn rank_of(&self, pid: usize) -> usize {
        self.map().rank_of(pid)
    }
}

/// A [`BspScope`] that can be narrowed to one processor group of a
/// partitioned machine — the capability the two-level sorts
/// (`sort::multilevel`) require of their execution scope.
///
/// `Comm` ties a scope to its backend's communicator type
/// ([`Communicator`] for the threaded [`BspCtx`],
/// `bsp::sim::SimCommunicator` for the simulator's `SimCtx`), so the
/// same generic program text runs on either backend while each backend
/// supplies its own group synchronization machinery.
pub trait GroupedScope<K: Key>: BspScope<K> {
    /// The backend's communicator type.
    type Comm: GroupPartition;
    /// The group-scoped scope produced by [`GroupedScope::enter_group`].
    type Group<'a>: BspScope<K>
    where
        Self: 'a;

    /// Enter this processor's group of `comm`: every subsequent
    /// `pid`/`nprocs`/`send`/`sync` through the returned scope is
    /// group-local.  `phase_prefix` is prepended to phase labels entered
    /// through the group scope (`""` keeps them unchanged).
    fn enter_group<'a>(&'a mut self, comm: &'a Self::Comm, phase_prefix: &str)
        -> Self::Group<'a>;
}

/// A partition of the `p`-processor machine into disjoint groups, with
/// the threaded engine's synchronization resources (one [`Barrier`] and
/// one superstep counter per group).
///
/// Construct once (outside `BspMachine::run`, so all threads share it),
/// then have every processor [`Communicator::enter`] its group inside
/// the SPMD program.  Groups are static for the communicator's
/// lifetime; a program may use several communicators in sequence.
pub struct Communicator {
    /// Process-unique id (ledger key component for group records).
    id: usize,
    /// The backend-independent partition.
    map: GroupMap,
    /// One barrier per group, sized to the group.
    barriers: Vec<Barrier>,
    /// One superstep counter per group, owned by the communicator and
    /// advanced by the barrier leader of each group sync.  Keying ledger
    /// records off these (instead of any per-thread counter) keeps the
    /// accounting correct even when sibling groups run different
    /// numbers of group supersteps and the threads are later regrouped
    /// by another communicator.
    steps: Vec<AtomicUsize>,
}

impl GroupPartition for Communicator {
    fn split_even(p: usize, num_groups: usize) -> Communicator {
        Communicator::from_map(GroupMap::split_even(p, num_groups))
    }

    fn from_map(map: GroupMap) -> Communicator {
        Communicator::from_map(map)
    }

    fn map(&self) -> &GroupMap {
        &self.map
    }
}

impl Communicator {
    /// Split `p` processors into `num_groups` contiguous near-even
    /// blocks ([`GroupMap::split_even`]).
    pub fn split_even(p: usize, num_groups: usize) -> Communicator {
        Communicator::from_map(GroupMap::split_even(p, num_groups))
    }

    /// Build a communicator from explicit member lists
    /// ([`GroupMap::from_groups`] validation applies).
    pub fn from_groups(groups: Vec<Vec<usize>>) -> Communicator {
        Communicator::from_map(GroupMap::from_groups(groups))
    }

    /// Wrap a validated partition with this engine's per-group barriers
    /// and superstep counters.
    pub fn from_map(map: GroupMap) -> Communicator {
        let barriers = (0..map.num_groups())
            .map(|g| Barrier::new(map.group_size(g)))
            .collect();
        let steps = (0..map.num_groups()).map(|_| AtomicUsize::new(0)).collect();
        Communicator { id: next_comm_id(), map, barriers, steps }
    }

    /// Total processors covered by the partition.
    pub fn nprocs(&self) -> usize {
        self.map.nprocs()
    }

    /// Number of groups.
    pub fn num_groups(&self) -> usize {
        self.map.num_groups()
    }

    /// Global pids of `group`, sorted ascending (rank order).
    pub fn members(&self, group: usize) -> &[usize] {
        self.map.members(group)
    }

    /// Size of `group`.
    pub fn group_size(&self, group: usize) -> usize {
        self.map.group_size(group)
    }

    /// The group index of global `pid`.
    pub fn group_of(&self, pid: usize) -> usize {
        self.map.group_of(pid)
    }

    /// `pid`'s rank within its group.
    pub fn rank_of(&self, pid: usize) -> usize {
        self.map.rank_of(pid)
    }

    /// Enter this processor's group: wrap `ctx` into a group-scoped
    /// [`BspScope`].  `phase_prefix` is prepended to every phase label
    /// entered through the group context (the multi-level sorts pass
    /// `"L2/"`, so the ledger separates level-2 phases from their
    /// level-1 namesakes); pass `""` to keep labels unchanged.
    pub fn enter<'c, 'w, K: Key>(
        &'c self,
        ctx: &'c mut BspCtx<'w, K>,
        phase_prefix: &str,
    ) -> GroupCtx<'c, 'w, K> {
        let pid = BspCtx::pid(ctx);
        assert!(
            pid < self.nprocs(),
            "pid {pid} outside the communicator's {} processors",
            self.nprocs()
        );
        GroupCtx {
            group: self.group_of(pid),
            rank: self.rank_of(pid),
            prefix: phase_prefix.to_string(),
            comm: self,
            ctx,
        }
    }
}

/// A group-scoped [`BspScope`]: ranks, barriers and message delivery
/// all restricted to one group of a [`Communicator`].
///
/// Obtained from [`Communicator::enter`]; borrows the underlying
/// [`BspCtx`] mutably, so the global scope is inaccessible (and the
/// group communication discipline enforceable) until the `GroupCtx` is
/// dropped.
pub struct GroupCtx<'c, 'w, K: Key> {
    comm: &'c Communicator,
    group: usize,
    rank: usize,
    prefix: String,
    ctx: &'c mut BspCtx<'w, K>,
}

impl<K: Key> GroupCtx<'_, '_, K> {
    /// This processor's global pid (its rank is [`BspScope::pid`]).
    pub fn global_pid(&self) -> usize {
        BspCtx::pid(self.ctx)
    }

    /// The index of the group this context is scoped to.
    pub fn group_index(&self) -> usize {
        self.group
    }
}

impl<K: Key> BspScope<K> for GroupCtx<'_, '_, K> {
    fn pid(&self) -> usize {
        self.rank
    }

    fn nprocs(&self) -> usize {
        self.comm.group_size(self.group)
    }

    fn charge(&mut self, ops: f64) {
        self.ctx.charge(ops);
    }

    fn phase(&mut self, name: &str) {
        if self.prefix.is_empty() {
            self.ctx.phase(name);
        } else {
            self.ctx.phase(&format!("{}{}", self.prefix, name));
        }
    }

    fn send(&mut self, dst: usize, payload: Payload<K>) {
        let members = self.comm.members(self.group);
        debug_assert!(dst < members.len(), "group send to invalid rank {dst}");
        self.ctx.send(members[dst], payload);
    }

    fn sync(&mut self, label: &str) {
        let members = self.comm.members(self.group);
        let scope = GroupScope {
            comm_id: self.comm.id,
            members,
            leader: members[0],
            barrier: &self.comm.barriers[self.group],
            step: &self.comm.steps[self.group],
        };
        self.ctx.sync_scoped(label, Some(&scope));
    }

    fn take_inbox(&mut self) -> Vec<(usize, Payload<K>)> {
        // A group drain only ever delivers member-written slots, so the
        // global sender pid always maps to a group rank; ascending pid
        // order is ascending rank order.
        self.ctx
            .take_inbox()
            .into_iter()
            .map(|(src, payload)| (self.comm.rank_of(src), payload))
            .collect()
    }
}

impl<'w, K: Key> GroupedScope<K> for BspCtx<'w, K> {
    type Comm = Communicator;
    type Group<'a>
        = GroupCtx<'a, 'w, K>
    where
        Self: 'a;

    fn enter_group<'a>(
        &'a mut self,
        comm: &'a Communicator,
        phase_prefix: &str,
    ) -> GroupCtx<'a, 'w, K> {
        comm.enter(self, phase_prefix)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bsp::engine::BspMachine;
    use crate::bsp::params::cray_t3d;

    fn machine(p: usize) -> BspMachine {
        BspMachine::new(cray_t3d(p))
    }

    #[test]
    fn split_even_p8_into_2x4() {
        let comm = Communicator::split_even(8, 2);
        assert_eq!(comm.nprocs(), 8);
        assert_eq!(comm.num_groups(), 2);
        assert_eq!(comm.members(0), &[0, 1, 2, 3]);
        assert_eq!(comm.members(1), &[4, 5, 6, 7]);
        for pid in 0..8 {
            assert_eq!(comm.group_of(pid), pid / 4);
            assert_eq!(comm.rank_of(pid), pid % 4);
        }
    }

    #[test]
    fn split_even_uneven_sizes() {
        let comm = Communicator::split_even(7, 3);
        assert_eq!(comm.members(0), &[0, 1, 2]);
        assert_eq!(comm.members(1), &[3, 4]);
        assert_eq!(comm.members(2), &[5, 6]);
    }

    #[test]
    #[should_panic(expected = "appears in more than one group")]
    fn overlapping_groups_rejected() {
        Communicator::from_groups(vec![vec![0, 1], vec![1]]);
    }

    #[test]
    #[should_panic(expected = "cannot split")]
    fn more_groups_than_procs_rejected() {
        Communicator::split_even(2, 4);
    }

    #[test]
    fn refine_splits_every_group_contiguously() {
        let coarse = GroupMap::split_even(16, 2);
        let fine = coarse.refine(4);
        assert_eq!(fine.num_groups(), 8);
        // Sub-groups of cell c occupy indices c*4..(c+1)*4, in order.
        for (g, start) in [(0, 0), (3, 6), (4, 8), (7, 14)] {
            assert_eq!(fine.members(g), &[start, start + 1]);
        }
        // Refinement respects the parent partition.
        for pid in 0..16 {
            assert_eq!(fine.group_of(pid) / 4, coarse.group_of(pid));
        }
    }

    #[test]
    fn refine_uneven_groups() {
        let coarse = GroupMap::split_even(10, 2);
        let fine = coarse.refine(3);
        assert_eq!(fine.members(0), &[0, 1]);
        assert_eq!(fine.members(1), &[2, 3]);
        assert_eq!(fine.members(2), &[4]);
        assert_eq!(fine.members(3), &[5, 6]);
    }

    #[test]
    #[should_panic(expected = "cannot refine")]
    fn refine_beyond_group_size_rejected() {
        GroupMap::split_even(4, 2).refine(3);
    }

    #[test]
    fn topology_roundtrips_and_builds_refinement_chain() {
        let t = Topology::new(&[8, 4, 4]);
        assert_eq!(t.depth(), 3);
        assert_eq!(t.nprocs(), 128);
        assert_eq!(t.label(), "8x4x4");
        assert_eq!(t.dims(), vec![8, 4, 4]);
        let comms: Vec<Communicator> = t.communicators();
        assert_eq!(comms.len(), 2);
        assert_eq!(comms[0].num_groups(), 8);
        assert_eq!(comms[1].num_groups(), 32);
        for pid in 0..128 {
            // Each level-1 cell sits wholly inside its level-0 cell.
            assert_eq!(comms[1].group_of(pid) / 4, comms[0].group_of(pid));
            // Leaf machines (cells of the last communicator) have 4 procs.
            assert_eq!(comms[1].group_size(comms[1].group_of(pid)), 4);
        }
        assert!(Topology::flat(64).communicators::<Communicator>().is_empty());
        assert_eq!(Topology::two_level(8, 2), Topology::new(&[2, 4]));
    }

    #[test]
    fn group_ranks_and_sizes_inside_a_run() {
        let comm = Communicator::split_even(8, 2);
        let run = machine(8).run(|ctx| {
            let g = comm.enter(ctx, "");
            (g.global_pid(), g.group_index(), g.pid(), g.nprocs())
        });
        for (pid, &(gpid, group, rank, size)) in run.outputs.iter().enumerate() {
            assert_eq!(gpid, pid);
            assert_eq!(group, pid / 4);
            assert_eq!(rank, pid % 4);
            assert_eq!(size, 4);
        }
    }

    #[test]
    fn group_all_to_all_stays_group_local() {
        // Each group runs its own all-to-all; nothing leaks across the
        // group boundary and senders arrive in rank order.
        let comm = Communicator::split_even(8, 2);
        let run = machine(8).run(|ctx| {
            let mut g = comm.enter(ctx, "");
            let me = g.pid();
            let group = g.group_index();
            let parts = (0..g.nprocs())
                .map(|dst| Payload::Keys(vec![(group * 100 + me * 10 + dst) as i32]))
                .collect();
            let inbox = g.all_to_all(parts, "ga2a");
            inbox
                .into_iter()
                .map(|(src, p)| (src, p.into_keys()[0]))
                .collect::<Vec<_>>()
        });
        for (pid, inbox) in run.outputs.iter().enumerate() {
            let (group, rank) = (pid / 4, pid % 4);
            assert_eq!(inbox.len(), 4, "pid={pid}");
            for (i, &(src, val)) in inbox.iter().enumerate() {
                assert_eq!(src, i, "inbox must be rank-ordered");
                assert_eq!(val as usize, group * 100 + src * 10 + rank);
            }
        }
    }

    #[test]
    fn stalled_sibling_does_not_block_group_syncs() {
        // Group 0 runs several group supersteps while group 1 never
        // syncs at all (it only computes).  If group syncs touched the
        // world barrier this would deadlock; instead the run completes
        // and group 0's exchanges are correct.
        let comm = Communicator::split_even(8, 2);
        let run = machine(8).run(|ctx| {
            let pid = ctx.pid();
            if pid < 4 {
                let mut g = comm.enter(ctx, "");
                let mut sum = 0i32;
                for round in 0..3 {
                    let dst = (g.pid() + 1) % g.nprocs();
                    g.send(dst, Payload::Keys(vec![round as i32 + g.pid() as i32]));
                    g.sync("ring");
                    sum += g.take_inbox().pop().unwrap().1.into_keys()[0];
                }
                sum
            } else {
                // The "stalled" sibling: no syncs, just local work.
                (0..1000).sum::<i32>() % 7
            }
        });
        for (pid, &out) in run.outputs.iter().enumerate() {
            if pid < 4 {
                let prev = (pid + 4 - 1) % 4;
                let expect: i32 = (0..3).map(|r| r + prev as i32).sum();
                assert_eq!(out, expect, "pid={pid}");
            }
        }
    }

    #[test]
    fn group_records_carry_round_and_procs() {
        let comm = Communicator::split_even(8, 2);
        let run = machine(8).run(|ctx| {
            // One global superstep, then two group-scoped ones.
            ctx.sync("global");
            let mut g = comm.enter(ctx, "L2/");
            g.phase("Ph5:Routing");
            let parts = (0..g.nprocs()).map(|_| Payload::Keys(vec![1i32])).collect();
            g.all_to_all(parts, "l2:route");
            g.sync("l2:done");
        });
        let global: Vec<_> =
            run.ledger.supersteps.iter().filter(|s| s.round.is_none()).collect();
        assert_eq!(global.len(), 1);
        assert_eq!(global[0].procs, 8);
        let grouped: Vec<_> =
            run.ledger.supersteps.iter().filter(|s| s.round.is_some()).collect();
        // 2 group supersteps × 2 groups.
        assert_eq!(grouped.len(), 4);
        assert!(grouped.iter().all(|s| s.procs == 4 && s.reporters == 4));
        let routes: Vec<_> = grouped.iter().filter(|s| s.label == "l2:route").collect();
        assert_eq!(routes.len(), 2);
        for s in &routes {
            assert_eq!(s.phase, "L2/Ph5:Routing");
            // Group-local all-to-all of 1 word to each of 4 ranks.
            assert_eq!(s.h_words, 4);
            assert_eq!(s.total_words, 16);
        }
    }

    #[test]
    fn phase_prefix_scopes_ledger_phases() {
        let comm = Communicator::split_even(4, 2);
        let run = machine(4).run(|ctx| {
            ctx.phase("Ph2:SeqSort");
            ctx.charge(10.0);
            let mut g = comm.enter(ctx, "L2/");
            g.phase("Ph2:SeqSort");
            g.charge(5.0);
            g.sync("l2:s");
        });
        assert_eq!(run.ledger.phases["Ph2:SeqSort"].max_ops, 10.0);
        assert_eq!(run.ledger.phases["L2/Ph2:SeqSort"].max_ops, 5.0);
    }
}
