//! BSP machine parameters `(p, L, g)` and the Cray T3D presets.
//!
//! The paper (§6) reports the T3D behaving as a BSP machine with
//! `(p, L, g)` = (16, 130 µs, 0.21 µs/int), (32, 175, 0.26),
//! (64, 364, 0.28), (128, 762, 0.34), communication data type a 64-bit
//! integer, and a computation rate of ~7 comparisons/µs (their quicksort
//! sorts 1M keys in ~3 s).  The cost of a superstep is
//! `max{L, x + g·h}` where `x` is the maximum number of basic operations
//! on any processor and `h` the maximum words into/out of any processor.
//!
//! The out-of-core subsystem (`ext/`) extends the tuple with the EM-BSP
//! third parameter `G_io`: the time to transfer one fixed-size block
//! between a processor's memory and its local disk (the EM-BSP/BSP* line
//! of work prices external supersteps as `max{L, x + g·h} + G·b` for `b`
//! block transfers).  In-core supersteps carry `b = 0` and price exactly
//! as before.

/// The BSP parameter tuple plus the operation-rate calibration that turns
/// abstract "basic computation steps" (comparisons) into microseconds.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct BspParams {
    /// Number of processors.
    pub p: usize,
    /// Synchronization latency L, in microseconds.
    pub l_us: f64,
    /// Per-word communication gap g, in microseconds per word.
    pub g_us_per_word: f64,
    /// Computation rate: comparisons per microsecond (T3D: ~7).
    pub comps_per_us: f64,
    /// EM-BSP block-I/O gap `G_io`: microseconds per
    /// [`crate::ext::DEFAULT_BLOCK_WORDS`]-word block moved to or from a
    /// processor's local store.  Calibrated by the `calibrate.rs` I/O
    /// probe on the threaded backend, synthetic on sim; zero for presets
    /// that never price external runs.
    pub io_us_per_block: f64,
}

impl BspParams {
    /// Parameters calibrated on the *host* by the experiment subsystem's
    /// micro-probes (`experiment::calibrate`): `l_us` from the barrier
    /// probe, `g_us_per_word` from the all-to-all slope fit and
    /// `comps_per_us` from the sequential-sort probe.  Predictions priced
    /// under these parameters are in host microseconds, directly
    /// comparable to measured wall-clock — the paper's measured-vs-
    /// predicted methodology on whatever machine runs the study.
    pub fn host(p: usize, l_us: f64, g_us_per_word: f64, comps_per_us: f64) -> BspParams {
        BspParams { p, l_us, g_us_per_word, comps_per_us, io_us_per_block: 0.0 }
    }

    /// Same parameters with the EM-BSP block-I/O gap set — builder-style
    /// so `host(..)` keeps its 4-argument in-core signature.
    pub fn with_io(self, io_us_per_block: f64) -> BspParams {
        BspParams { io_us_per_block, ..self }
    }

    /// Measurement-only placeholder parameters (L = g = G_io = 0,
    /// rate = 1): used by the calibration probes themselves, which need a
    /// machine to *execute* on before any prices exist.  Never price a
    /// prediction with these.
    pub fn unit(p: usize) -> BspParams {
        BspParams {
            p,
            l_us: 0.0,
            g_us_per_word: 0.0,
            comps_per_us: 1.0,
            io_us_per_block: 0.0,
        }
    }

    /// The effective machine seen by a processor *group* of `p_eff < p`
    /// processors (`bsp::group::Communicator`): same communication gap
    /// `g` and computation rate, but the synchronization latency scales
    /// down log-linearly in the participant count —
    /// `L' = L · lg(p_eff)/lg(p)` — matching the roughly `lg p` growth
    /// of L across the paper's measured T3D points (130→762 µs for
    /// 16→128 procs).  A barrier over fewer processors is cheaper; a
    /// group exchange still pays the full per-word gap.  This is the
    /// pricing rule the ledger applies to group-scoped supersteps
    /// (`SuperstepRecord::predicted_us`), deliberately conservative: it
    /// never scales below the two-processor point.
    pub fn scaled_to(&self, p_eff: usize) -> BspParams {
        if p_eff >= self.p || self.p <= 2 {
            return BspParams { p: p_eff.min(self.p).max(1), ..*self };
        }
        let num = (p_eff.max(2) as f64).log2();
        let den = (self.p as f64).log2();
        BspParams {
            p: p_eff,
            l_us: self.l_us * (num / den).min(1.0),
            ..*self
        }
    }

    /// Cost (µs) of one superstep with max compute `x` (comparisons) and
    /// max fan-in/out `h` (words): `max{L, x/rate + g·h}` (§1.1).
    pub fn superstep_cost_us(&self, x_comps: f64, h_words: u64) -> f64 {
        let t = x_comps / self.comps_per_us + self.g_us_per_word * h_words as f64;
        t.max(self.l_us)
    }

    /// Time (µs) to execute `x` comparisons locally.
    pub fn comp_us(&self, x_comps: f64) -> f64 {
        x_comps / self.comps_per_us
    }

    /// Time (µs) to realize an `h`-relation.
    pub fn comm_us(&self, h_words: u64) -> f64 {
        self.g_us_per_word * h_words as f64
    }

    /// Time (µs) to transfer `blocks` fixed-size blocks between memory
    /// and the local store (the EM-BSP `G·b` term; 0 for in-core steps).
    pub fn io_us(&self, blocks: u64) -> f64 {
        self.io_us_per_block * blocks as f64
    }
}

/// Measured Cray T3D parameter points from §6 of the paper.
pub const T3D_POINTS: [(usize, f64, f64); 4] = [
    (16, 130.0, 0.21),
    (32, 175.0, 0.26),
    (64, 364.0, 0.28),
    (128, 762.0, 0.34),
];

/// T3D computation rate: 7 comparisons per µs (§6: "7 comparisons per
/// microsecond").
pub const T3D_COMPS_PER_US: f64 = 7.0;

/// Synthetic EM-BSP block-I/O gap for the T3D preset, in µs per
/// 4096-word (32 KiB) block.  The paper never measures disks; this is a
/// documented stand-in at ~100 MB/s sustained local-disk bandwidth
/// (32 KiB / 100 MB/s ≈ 327 µs), so simulator external runs price
/// deterministically and visibly dominate over `g` for block-sized
/// payloads.  Host runs replace it with the calibrated probe value.
pub const T3D_IO_US_PER_BLOCK: f64 = 327.0;

/// BSP parameters of the paper's Cray T3D for `p` processors.
///
/// For the measured points (16/32/64/128) the paper's values are used
/// verbatim; for other `p` (the paper also runs p = 8) we interpolate /
/// extrapolate log-linearly in `p`, which tracks the roughly linear growth
/// of both L and g in the measured range.  The extrapolation choice is
/// documented in DESIGN.md §2 and only affects the p = 8 rows of
/// Tables 3/9/10/11.
pub fn cray_t3d(p: usize) -> BspParams {
    let (l_us, g_us) = interp_t3d(p);
    BspParams {
        p,
        l_us,
        g_us_per_word: g_us,
        comps_per_us: T3D_COMPS_PER_US,
        io_us_per_block: T3D_IO_US_PER_BLOCK,
    }
}

fn interp_t3d(p: usize) -> (f64, f64) {
    let pts = &T3D_POINTS;
    if let Some(&(_, l, g)) = pts.iter().find(|&&(pp, _, _)| pp == p) {
        return (l, g);
    }
    let x = (p as f64).log2();
    // Piecewise-linear in lg p, clamped extrapolation at the ends.
    let coords: Vec<(f64, f64, f64)> = pts
        .iter()
        .map(|&(pp, l, g)| ((pp as f64).log2(), l, g))
        .collect();
    let seg = |x0: f64, y0: f64, x1: f64, y1: f64, x: f64| y0 + (y1 - y0) * (x - x0) / (x1 - x0);
    let (mut l, mut g) = (coords[0].1, coords[0].2);
    if x <= coords[0].0 {
        let (x0, l0, g0) = coords[0];
        let (x1, l1, g1) = coords[1];
        l = seg(x0, l0, x1, l1, x).max(10.0);
        g = seg(x0, g0, x1, g1, x).max(0.05);
    } else if x >= coords[3].0 {
        let (x0, l0, g0) = coords[2];
        let (x1, l1, g1) = coords[3];
        l = seg(x0, l0, x1, l1, x);
        g = seg(x0, g0, x1, g1, x);
    } else {
        for w in coords.windows(2) {
            let (x0, l0, g0) = w[0];
            let (x1, l1, g1) = w[1];
            if (x0..=x1).contains(&x) {
                l = seg(x0, l0, x1, l1, x);
                g = seg(x0, g0, x1, g1, x);
                break;
            }
        }
    }
    (l, g)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_points_match_paper() {
        for &(p, l, g) in &T3D_POINTS {
            let params = cray_t3d(p);
            assert_eq!(params.l_us, l);
            assert_eq!(params.g_us_per_word, g);
        }
    }

    #[test]
    fn p8_extrapolation_is_sane() {
        let params = cray_t3d(8);
        assert!(params.l_us > 10.0 && params.l_us < 130.0, "L(8)={}", params.l_us);
        assert!(params.g_us_per_word > 0.05 && params.g_us_per_word < 0.21);
    }

    #[test]
    fn interpolation_is_monotone() {
        let mut last_l = 0.0;
        let mut last_g = 0.0;
        for p in [8, 16, 24, 32, 48, 64, 96, 128, 256] {
            let params = cray_t3d(p);
            assert!(params.l_us >= last_l, "L not monotone at p={p}");
            assert!(params.g_us_per_word >= last_g, "g not monotone at p={p}");
            last_l = params.l_us;
            last_g = params.g_us_per_word;
        }
    }

    #[test]
    fn superstep_cost_floors_at_l() {
        let params = cray_t3d(16);
        assert_eq!(params.superstep_cost_us(0.0, 0), 130.0);
        // 1M comparisons at 7/µs ≈ 142857 µs >> L.
        let c = params.superstep_cost_us(1_000_000.0, 0);
        assert!((c - 1_000_000.0 / 7.0).abs() < 1e-6);
    }

    #[test]
    fn comm_cost_is_linear_in_h() {
        let params = cray_t3d(64);
        assert!((params.comm_us(1000) - 280.0).abs() < 1e-9);
    }

    #[test]
    fn io_cost_is_linear_in_blocks_and_defaults_off() {
        let t3d = cray_t3d(16);
        assert_eq!(t3d.io_us_per_block, T3D_IO_US_PER_BLOCK);
        assert!((t3d.io_us(10) - 3270.0).abs() < 1e-9);
        // host()/unit() stay in-core unless with_io() arms the G_io term.
        let host = BspParams::host(4, 5.0, 0.01, 100.0);
        assert_eq!(host.io_us(1_000_000), 0.0);
        assert_eq!(host.with_io(50.0).io_us(4), 200.0);
        assert_eq!(BspParams::unit(8).io_us_per_block, 0.0);
        // with_io leaves the in-core tuple untouched.
        assert_eq!(host.with_io(50.0).l_us, host.l_us);
    }

    #[test]
    fn scaled_to_shrinks_l_keeps_g_and_rate() {
        let params = cray_t3d(128);
        let group = params.scaled_to(8);
        assert_eq!(group.p, 8);
        assert!(group.l_us < params.l_us && group.l_us > 0.0);
        // L' = 762 · 3/7.
        assert!((group.l_us - 762.0 * 3.0 / 7.0).abs() < 1e-9, "L'={}", group.l_us);
        assert_eq!(group.g_us_per_word, params.g_us_per_word);
        assert_eq!(group.comps_per_us, params.comps_per_us);
    }

    #[test]
    fn scaled_to_is_monotone_and_identity_at_full_p() {
        let params = cray_t3d(64);
        assert_eq!(params.scaled_to(64), params);
        let mut last = 0.0;
        for p_eff in [2usize, 4, 8, 16, 32, 64] {
            let l = params.scaled_to(p_eff).l_us;
            assert!(l >= last, "L not monotone at p_eff={p_eff}");
            last = l;
        }
    }
}
