//! The BSP machine substrate (DESIGN.md §4.1).
//!
//! * [`params`] — `(p, L, g)` parameters and Cray T3D presets,
//! * [`msg`] — message payloads and the §5.1.1 tagged sample record,
//! * [`ledger`] — superstep/phase cost accounting,
//! * [`engine`] — the threaded SPMD superstep executor and the
//!   [`BspScope`] contract the algorithms are generic over,
//! * [`group`] — processor-group communicators: disjoint sub-machines
//!   with group ranks, group barriers and group-scoped message delivery
//!   (the substrate of the multi-level sorts).
//!
//! The same program runs *really* (threads, genuine data movement) and is
//! priced *predictively* (`max{L, x + g·h}` per superstep), which is how
//! the paper's T3D tables are regenerated on non-T3D hardware.

pub mod engine;
pub mod group;
pub mod ledger;
pub mod msg;
pub mod params;

pub use engine::{BspCtx, BspMachine, BspRun, BspScope};
pub use group::{Communicator, GroupCtx};
pub use ledger::{Ledger, PhaseComparison, PhaseRecord, SuperstepRecord};
pub use msg::{Payload, SampleRec};
pub use params::{cray_t3d, BspParams};
