//! The BSP machine substrate (DESIGN.md §4.1).
//!
//! * [`params`] — `(p, L, g)` parameters and Cray T3D presets,
//! * [`msg`] — message payloads and the §5.1.1 tagged sample record,
//! * [`ledger`] — superstep/phase cost accounting,
//! * [`engine`] — the threaded SPMD superstep executor.
//!
//! The same program runs *really* (threads, genuine data movement) and is
//! priced *predictively* (`max{L, x + g·h}` per superstep), which is how
//! the paper's T3D tables are regenerated on non-T3D hardware.

pub mod engine;
pub mod ledger;
pub mod msg;
pub mod params;

pub use engine::{BspCtx, BspMachine, BspRun};
pub use ledger::{Ledger, PhaseComparison, PhaseRecord, SuperstepRecord};
pub use msg::{Payload, SampleRec};
pub use params::{cray_t3d, BspParams};
