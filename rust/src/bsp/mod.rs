//! The BSP machine substrate (DESIGN.md §4.1).
//!
//! * [`params`] — `(p, L, g)` parameters and Cray T3D presets,
//! * [`msg`] — message payloads and the §5.1.1 tagged sample record,
//! * [`ledger`] — superstep/phase cost accounting,
//! * [`engine`] — the threaded SPMD superstep executor and the
//!   [`BspScope`] contract the algorithms are generic over,
//! * [`group`] — processor-group communicators: disjoint sub-machines
//!   with group ranks, group barriers and group-scoped message delivery
//!   (the substrate of the multi-level sorts),
//! * [`sim`] — the deterministic single-process simulator backend:
//!   the same SPMD programs on virtual processors with virtual time,
//!   bit-for-bit reproducible at any `p` (the conformance suite's
//!   substrate at `p` up to 1024),
//! * [`service`] — the persistent engine pool: parked worker crews, a
//!   bounded job queue with admission control, FIFO dispatch with
//!   shared-superstep batching of small jobs, and recycled slot-matrix
//!   scratch (the substrate of the crate-level `Sorter` façade).
//!
//! The same program runs *really* (threads, genuine data movement) and is
//! priced *predictively* (`max{L, x + g·h}` per superstep), which is how
//! the paper's T3D tables are regenerated on non-T3D hardware.

pub mod engine;
pub mod group;
pub mod ledger;
pub mod msg;
pub mod params;
pub mod service;
pub mod sim;

pub use engine::{BspCtx, BspMachine, BspRun, BspScope};
pub use service::{Engine, EngineConfig, EngineStats, JobHandle};
pub use group::{
    Communicator, GroupCtx, GroupMap, GroupPartition, GroupedScope, Topology, MAX_TOPOLOGY_DEPTH,
};
pub use ledger::{Ledger, PhaseComparison, PhaseRecord, SuperstepRecord};
pub use msg::{Payload, SampleRec};
pub use params::{cray_t3d, BspParams};
pub use sim::{SimCommunicator, SimCtx, SimGroupCtx, SimMachine, SkewSpec};

/// Which execution backend runs an SPMD program: the threaded engine
/// (real threads, measured wall-clock) or the deterministic simulator
/// (one process, virtual processors, virtual time — reproducible at any
/// `p`).  Threaded through `sort::config`, `experiment::spec`/`run` and
/// the CLI's `--backend` flag.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub enum Backend {
    /// `bsp::engine::BspMachine` — `p` OS threads, genuine contention.
    #[default]
    Threaded,
    /// `bsp::sim::SimMachine` — deterministic single-process simulator.
    Sim,
}

/// Every backend, in report order.
pub const ALL_BACKENDS: [Backend; 2] = [Backend::Threaded, Backend::Sim];

impl Backend {
    /// Stable CLI/report tag (`threaded`, `sim`).
    pub fn tag(&self) -> &'static str {
        match self {
            Backend::Threaded => "threaded",
            Backend::Sim => "sim",
        }
    }

    /// Parse a CLI/report tag; `None` for unknown tags.
    pub fn parse(s: &str) -> Option<Backend> {
        match s.to_ascii_lowercase().as_str() {
            "threaded" | "engine" | "thread" => Some(Backend::Threaded),
            "sim" | "simulator" | "simulated" => Some(Backend::Sim),
            _ => None,
        }
    }
}
