//! The paper's operation-charging policy (§1.1, "charging policy").
//!
//! * sorting `n` keys sequentially: `n lg n` comparisons,
//! * merging `q` lists of total size `n`: `n lg q`,
//! * binary search over a sorted sequence of length `n-1`: `⌈lg n⌉`,
//! * parallel-prefix step / single comparison: `O(1)` charged as 1,
//! * radixsort: linear, calibrated to the T3D measurement (see below).
//!
//! These analytic charges (not instrumented counts) feed the predicted
//! cost `max{L, x + g·h}` — exactly how the paper's theory section prices
//! its algorithms, so predicted tables are comparable to Props 5.1/5.3.

use crate::util::{ceil_log2, lg};

/// Charge for sorting `n` keys with a comparison sort: `n lg n`.
pub fn sort_charge(n: usize) -> f64 {
    let nf = n as f64;
    nf * lg(nf)
}

/// Charge for radix-sorting `n` 32-bit keys.
///
/// Calibration: Table 6 reports \[DSR\] Ph2 (radixsort of 8M/32 = 256K keys
/// per processor) at 0.560 s vs \[DSQ\]'s 0.675 s for quicksort, i.e. radix
/// is 0.83× the `n lg n = 18n` quicksort charge at that size → ≈ 15n
/// comparison-equivalents (DESIGN.md §4.2; 4 passes × counting+permute).
pub const RADIX_CHARGE_PER_KEY: f64 = 15.0;

pub fn radix_charge(n: usize) -> f64 {
    n as f64 * RADIX_CHARGE_PER_KEY
}

/// Calibrated constant for multi-way merging: the loser tree performs
/// `lg q` *comparisons* per key, but the T3D-observed Ph6 times (Tables
/// 4–7: Ph6/Ph2 = 0.58/0.71/0.86 at p = 32/64/128 for \[RSR\]) imply
/// ~1.75 comparison-equivalents per comparison once key movement and
/// tree updates are priced — consistent across both radix and quicksort
/// variants (DESIGN.md §4.2 calibration note).
pub const MERGE_CHARGE_FACTOR: f64 = 1.75;

/// Charge for merging `q` sorted lists of total size `n`:
/// `1.75 · n lg q` (calibrated; the paper's analysis uses `n lg q`).
pub fn merge_charge(n: usize, q: usize) -> f64 {
    MERGE_CHARGE_FACTOR * n as f64 * lg(q as f64).max(1.0)
}

/// Charge for a binary search in a sorted sequence of length `n`: `⌈lg n⌉`.
pub fn bsearch_charge(n: usize) -> f64 {
    ceil_log2(n.max(1) as u64) as f64
}

/// Charge for a linear pass over `n` items.
pub fn linear_charge(n: usize) -> f64 {
    n as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sort_charge_is_nlgn() {
        assert_eq!(sort_charge(1024), 1024.0 * 10.0);
        assert_eq!(sort_charge(0), 0.0);
        assert_eq!(sort_charge(1), 0.0);
    }

    #[test]
    fn merge_charge_is_calibrated_nlgq() {
        assert_eq!(merge_charge(1000, 8), 1.75 * 3000.0);
        // q = 1: still a linear touch.
        assert_eq!(merge_charge(4, 1), 7.0);
    }

    #[test]
    fn radix_is_cheaper_than_quick_at_256k() {
        let n = 256 * 1024;
        assert!(radix_charge(n) < sort_charge(n));
        // ratio ≈ 15/18 = 0.83, the T3D-observed Ph2 ratio.
        let ratio = radix_charge(n) / sort_charge(n);
        assert!((0.80..0.87).contains(&ratio), "ratio={ratio}");
    }

    #[test]
    fn bsearch_charge_values() {
        assert_eq!(bsearch_charge(1024), 10.0);
        assert_eq!(bsearch_charge(1), 0.0);
        assert_eq!(bsearch_charge(1025), 11.0);
    }
}
