//! The paper's operation-charging policy (§1.1, "charging policy").
//!
//! * sorting `n` keys sequentially: `n lg n` comparisons,
//! * merging `q` lists of total size `n`: `n lg q`,
//! * binary search over a sorted sequence of length `n-1`: `⌈lg n⌉`,
//! * parallel-prefix step / single comparison: `O(1)` charged as 1,
//! * radixsort: linear, calibrated to the T3D measurement (see below).
//!
//! These analytic charges (not instrumented counts) feed the predicted
//! cost `max{L, x + g·h}` — exactly how the paper's theory section prices
//! its algorithms, so predicted tables are comparable to Props 5.1/5.3.

use crate::util::{ceil_log2, lg};

/// Charge for sorting `n` keys with a comparison sort: `n lg n`.
pub fn sort_charge(n: usize) -> f64 {
    let nf = n as f64;
    nf * lg(nf)
}

/// Charge for radix-sorting `n` 32-bit keys.
///
/// Calibration: Table 6 reports \[DSR\] Ph2 (radixsort of 8M/32 = 256K keys
/// per processor) at 0.560 s vs \[DSQ\]'s 0.675 s for quicksort, i.e. radix
/// is 0.83× the `n lg n = 18n` quicksort charge at that size → ≈ 15n
/// comparison-equivalents (DESIGN.md §4.2; 4 passes × counting+permute).
pub const RADIX_CHARGE_PER_KEY: f64 = 15.0;

pub fn radix_charge(n: usize) -> f64 {
    n as f64 * RADIX_CHARGE_PER_KEY
}

/// Calibrated constant for multi-way merging: the loser tree performs
/// `lg q` *comparisons* per key, but the T3D-observed Ph6 times (Tables
/// 4–7: Ph6/Ph2 = 0.58/0.71/0.86 at p = 32/64/128 for \[RSR\]) imply
/// ~1.75 comparison-equivalents per comparison once key movement and
/// tree updates are priced — consistent across both radix and quicksort
/// variants (DESIGN.md §4.2 calibration note).
pub const MERGE_CHARGE_FACTOR: f64 = 1.75;

/// Charge for merging `q` sorted lists of total size `n`:
/// `1.75 · n lg q` (calibrated; the paper's analysis uses `n lg q`).
///
/// For `q ≤ 1` there is nothing to merge — the "merge" is a straight
/// copy of the single (or empty) run, so the charge is the linear `n`,
/// not a full merge pass.  The external-memory merge prices per-pass
/// fan-in through this function and hits the degenerate case whenever a
/// processor owns a single run.
pub fn merge_charge(n: usize, q: usize) -> f64 {
    if q <= 1 {
        return n as f64;
    }
    MERGE_CHARGE_FACTOR * n as f64 * lg(q as f64).max(1.0)
}

/// Per-key, per-level charge of the in-place block partitioner
/// (`seq::ips`).  One level is classification (read + buffer write +
/// block flush) plus its share of the block permutation and cleanup —
/// about one counting pass plus one permutation pass of the LSD kernel,
/// so a third of the 15-op four-pass [`RADIX_CHARGE_PER_KEY`]
/// calibration per level.
pub const IPS_CHARGE_PER_KEY_LEVEL: f64 = 5.0;

/// Recursion levels the block partitioner needs for `n` keys over an
/// image of `passes` 8-bit digits: one digit per level until buckets
/// reach the quicksort fallback, ⌈lg n / 8⌉, at least 1 and never more
/// than the image width.  Unlike LSD radix (always `passes` passes),
/// the MSD recursion depth follows the *distinguishing* prefix, which
/// is what makes it cheaper on wide domains.
pub fn ips_levels(n: usize, passes: u32) -> u32 {
    if n <= 1 {
        return 1;
    }
    ceil_log2(n as u64).div_ceil(8).clamp(1, passes.max(1))
}

/// Charge for IPS-sorting `n` keys of the study's 4-digit (32-bit)
/// reference domain; wider domains go through [`ips_charge_for`].
pub fn ips_charge(n: usize) -> f64 {
    ips_charge_for(n, 4)
}

/// Charge for IPS-sorting `n` keys whose radix image spans `passes`
/// 8-bit digits: `n · 5 · ips_levels(n, passes)`.
pub fn ips_charge_for(n: usize, passes: u32) -> f64 {
    n as f64 * IPS_CHARGE_PER_KEY_LEVEL * ips_levels(n, passes) as f64
}

/// Charge for a binary search in a sorted sequence of length `n`: `⌈lg n⌉`.
pub fn bsearch_charge(n: usize) -> f64 {
    ceil_log2(n.max(1) as u64) as f64
}

/// Charge for a linear pass over `n` items.
pub fn linear_charge(n: usize) -> f64 {
    n as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sort_charge_is_nlgn() {
        assert_eq!(sort_charge(1024), 1024.0 * 10.0);
        assert_eq!(sort_charge(0), 0.0);
        assert_eq!(sort_charge(1), 0.0);
    }

    #[test]
    fn merge_charge_is_calibrated_nlgq() {
        assert_eq!(merge_charge(1000, 8), 1.75 * 3000.0);
    }

    #[test]
    fn merge_charge_degenerate_fanin_is_a_copy() {
        // q ≤ 1: nothing to merge — a straight copy charges n, not a
        // full 1.75·n merge pass (regression: the old policy priced a
        // single-run "merge" as 1.75·n·max(lg 1, 1) = 1.75n).
        assert_eq!(merge_charge(4, 1), 4.0);
        assert_eq!(merge_charge(4, 0), 4.0);
        assert_eq!(merge_charge(0, 1), 0.0);
        // q = 2 is the boundary back to real merging: lg 2 = 1, so the
        // calibrated 1.75·n applies from two runs upward.
        assert_eq!(merge_charge(1000, 2), 1.75 * 1000.0);
    }

    #[test]
    fn radix_is_cheaper_than_quick_at_256k() {
        let n = 256 * 1024;
        assert!(radix_charge(n) < sort_charge(n));
        // ratio ≈ 15/18 = 0.83, the T3D-observed Ph2 ratio.
        let ratio = radix_charge(n) / sort_charge(n);
        assert!((0.80..0.87).contains(&ratio), "ratio={ratio}");
    }

    #[test]
    fn ips_levels_track_the_distinguishing_prefix() {
        // 1e6 keys: ⌈20/8⌉ = 3 levels regardless of image width beyond
        // 3 digits; tiny inputs clamp to one level.
        assert_eq!(ips_levels(1_000_000, 4), 3);
        assert_eq!(ips_levels(1_000_000, 8), 3);
        assert_eq!(ips_levels(1_000_000, 2), 2);
        assert_eq!(ips_levels(1, 8), 1);
        assert_eq!(ips_levels(0, 8), 1);
        assert_eq!(ips_levels(usize::MAX, 8), 8);
    }

    #[test]
    fn ips_beats_lsd_radix_on_wide_domains_at_1e6() {
        // The acceptance criterion's analytic counterpart: at n = 1e6
        // an 8-digit (u64) LSD radix charges 30n while IPS charges
        // 3 levels · 5 = 15n, and on the 4-digit i32 calibration the
        // two tie exactly.
        let n = 1_000_000;
        assert!(ips_charge_for(n, 8) < radix_charge(n) * 2.0);
        assert_eq!(ips_charge_for(n, 4), radix_charge(n));
        // IPS also undercuts the n lg n comparison sort there.
        assert!(ips_charge_for(n, 8) < sort_charge(n));
    }

    #[test]
    fn bsearch_charge_values() {
        assert_eq!(bsearch_charge(1024), 10.0);
        assert_eq!(bsearch_charge(1), 0.0);
        assert_eq!(bsearch_charge(1025), 11.0);
    }
}
