//! Author-style quicksort (the paper's `SORT_SEQ` comparison variant).
//!
//! Matches the construction of the paper's ANSI C implementation:
//! median-of-three pivoting [18], explicit small-partition insertion-sort
//! cutoff, and recursion on the smaller side only (the larger side loops)
//! so stack depth is `O(lg n)`.  Generic over any `Copy + Ord` key (the
//! comparison sort needs nothing else from the [`crate::key::Key`]
//! contract); sorts in place.
//!
//! The paper's T3D build sorts 1M keys in ~3 s ≈ 7 comparisons/µs; our
//! charge policy prices this sort at `n lg n` comparisons (ops.rs).

const INSERTION_CUTOFF: usize = 24;

/// Sort `a` ascending, in place.
pub fn quicksort<T: Copy + Ord>(a: &mut [T]) {
    if a.len() > 1 {
        quicksort_range(a);
    }
}

fn quicksort_range<T: Copy + Ord>(mut a: &mut [T]) {
    loop {
        let n = a.len();
        if n <= INSERTION_CUTOFF {
            insertion_sort(a);
            return;
        }
        let pivot = median_of_three(a);
        let mid = hoare_partition(a, pivot);
        // Fat-pivot skip: exclude the run of pivot-equal keys bordering
        // the split so duplicate-heavy input ([DD], all-equal) stays
        // linear without paying three-way swap traffic on random data.
        let mut lo_end = mid;
        while lo_end > 0 && a[lo_end - 1] == pivot {
            lo_end -= 1;
        }
        let mut hi_start = mid;
        while hi_start < n && a[hi_start] == pivot {
            hi_start += 1;
        }
        if lo_end < n - hi_start {
            let (lo, rest) = a.split_at_mut(lo_end);
            quicksort_range(lo);
            a = &mut rest[hi_start - lo_end..];
        } else {
            let (rest, hi) = a.split_at_mut(hi_start);
            quicksort_range(hi);
            a = &mut rest[..lo_end];
        }
    }
}

/// Hoare partition around `pivot`: returns `m` with `a[..m] <= pivot` and
/// `a[m..] >= pivot`, `0 < m < n`.  Unchecked pointer scans — safe
/// because `median_of_three` guarantees both scan directions hit a
/// stopper (`a[mid] == pivot`, `a[0] <= pivot <= a[n-1]`) and the swap
/// re-establishes stoppers on both sides.
fn hoare_partition<T: Copy + Ord>(a: &mut [T], pivot: T) -> usize {
    let n = a.len();
    let ptr = a.as_mut_ptr();
    unsafe {
        let mut i = 0isize;
        let mut j = (n - 1) as isize;
        loop {
            while *ptr.offset(i) < pivot {
                i += 1;
            }
            while *ptr.offset(j) > pivot {
                j -= 1;
            }
            if i >= j {
                return (j + 1) as usize;
            }
            std::ptr::swap(ptr.offset(i), ptr.offset(j));
            i += 1;
            j -= 1;
            if i > j {
                return i as usize;
            }
        }
    }
}

/// Median of first/middle/last (also sorts those three positions).
fn median_of_three<T: Copy + Ord>(a: &mut [T]) -> T {
    let n = a.len();
    let (lo, mid, hi) = (0, n / 2, n - 1);
    if a[mid] < a[lo] {
        a.swap(mid, lo);
    }
    if a[hi] < a[lo] {
        a.swap(hi, lo);
    }
    if a[hi] < a[mid] {
        a.swap(hi, mid);
    }
    a[mid]
}

/// Insertion sort for small partitions.
pub fn insertion_sort<T: Copy + Ord>(a: &mut [T]) {
    for i in 1..a.len() {
        let key = a[i];
        let mut j = i;
        while j > 0 && a[j - 1] > key {
            a[j] = a[j - 1];
            j -= 1;
        }
        a[j] = key;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::{arb_keys, check};
    use crate::util::rng::SplitMix64;

    fn is_sorted(a: &[i32]) -> bool {
        a.windows(2).all(|w| w[0] <= w[1])
    }

    #[test]
    fn sorts_empty_and_singleton() {
        let mut empty: Vec<i32> = vec![];
        quicksort(&mut empty);
        let mut one = vec![42];
        quicksort(&mut one);
        assert_eq!(one, vec![42]);
    }

    #[test]
    fn sorts_random_inputs_property() {
        check("quicksort-random", |rng| {
            let mut keys = arb_keys(rng, 0, 2000, i32::MIN, i32::MAX);
            let mut expect = keys.clone();
            expect.sort_unstable();
            quicksort(&mut keys);
            assert_eq!(keys, expect);
        });
    }

    #[test]
    fn sorts_duplicate_heavy_property() {
        check("quicksort-dups", |rng| {
            let mut keys = arb_keys(rng, 0, 2000, 0, 3);
            let mut expect = keys.clone();
            expect.sort_unstable();
            quicksort(&mut keys);
            assert_eq!(keys, expect);
        });
    }

    #[test]
    fn sorts_adversarial_patterns() {
        for n in [2usize, 3, 25, 26, 100, 1000] {
            // already sorted
            let mut a: Vec<i32> = (0..n as i32).collect();
            quicksort(&mut a);
            assert!(is_sorted(&a));
            // reverse sorted
            let mut b: Vec<i32> = (0..n as i32).rev().collect();
            quicksort(&mut b);
            assert!(is_sorted(&b));
            // all equal
            let mut c = vec![7i32; n];
            quicksort(&mut c);
            assert_eq!(c, vec![7i32; n]);
            // organ pipe
            let mut d: Vec<i32> = (0..n as i32 / 2).chain((0..n as i32 / 2).rev()).collect();
            quicksort(&mut d);
            assert!(is_sorted(&d));
        }
    }

    #[test]
    fn sorts_total_ordered_f64_including_nan() {
        use crate::key::F64;
        let mut a = vec![
            F64(1.0),
            F64(f64::NAN),
            F64(-0.0),
            F64(0.0),
            F64(f64::NEG_INFINITY),
        ];
        quicksort(&mut a);
        assert!(a.windows(2).all(|w| w[0] <= w[1]));
        assert_eq!(a[0], F64(f64::NEG_INFINITY));
        assert_eq!(a[1], F64(-0.0));
        assert_eq!(a[4], F64(f64::NAN));
    }

    #[test]
    fn sorts_extreme_values() {
        let mut a = vec![i32::MAX, i32::MIN, 0, -1, 1, i32::MAX, i32::MIN];
        quicksort(&mut a);
        assert_eq!(a, vec![i32::MIN, i32::MIN, -1, 0, 1, i32::MAX, i32::MAX]);
    }

    #[test]
    fn large_duplicate_blocks_terminate() {
        // Regression guard against quadratic/non-terminating behaviour on
        // long runs of equal keys.
        let mut rng = SplitMix64::new(3);
        let mut a: Vec<i32> = (0..200_000).map(|_| rng.below(2) as i32).collect();
        quicksort(&mut a);
        assert!(is_sorted(&a));
    }
}
