//! In-place block-partitioning MSD radix sort (the `Ips` local-sort
//! engine), after the IPS²Ra family of in-place sample/radix sorters
//! ("Engineering In-Place (Shared-Memory) Sorting Algorithms" and "A
//! study of integer sorting on multicores" — see PAPERS.md).
//!
//! The sorter works on the order-preserving u64 [`RadixKey`] image and
//! partitions a slice by one 8-bit digit per recursion level, in place,
//! using four phases:
//!
//! 1. **Digit planning** ([`plan_digit`]): one min/max pass over the
//!    images picks the most-significant *distinguishing* byte.  IPS²Ra
//!    estimates this prefix from a sample; we pay the exact linear pass
//!    (branch-free, same O(n) as classification) so the chosen digit is
//!    always a splitting digit — at least two buckets are non-empty, so
//!    recursion strictly shrinks, and constant prefix bytes (e.g. the
//!    duplicate benchmarks' zeroed high words) are skipped outright.
//!    `None` means every image is equal, hence — images being injective
//!    on each key domain — every *key* is equal and the slice is sorted.
//! 2. **Classification** ([`classify`]): a single left-to-right scan
//!    moves each key into one of 256 per-bucket buffer blocks of
//!    [`BLOCK`] keys; a full buffer is flushed back into the array at
//!    the write frontier (which never overtakes the read cursor) and
//!    its bucket recorded in a tag list.  After the scan the array
//!    prefix holds full blocks in scan order and every partial bucket
//!    remainder sits in its buffer.
//! 3. **Block permutation** ([`permute_blocks`]): cycle-following with
//!    one spare block rearranges the full blocks so each bucket's
//!    blocks are contiguous and in bucket order.
//! 4. **Cleanup** ([`cleanup`]): full runs are shifted onto the exact
//!    bucket boundaries (highest bucket first, so no unread run is
//!    clobbered) and the partial buffers drain into the tail gap of
//!    their bucket, leaving bucket `d` exactly at
//!    `[start_d, start_d + count_d)`.
//!
//! Buckets at or below [`FALLBACK_CUTOFF`] keys are finished by
//! [`seq::quicksort`](crate::seq::quicksort) instead of recursing.
//! Charging for the engine lives in [`super::ops::ips_charge_for`]; the
//! BSP layers select it through `SeqSortKind::Ips` /
//! `sort::LocalSortEngine::Ips`.
#![warn(missing_docs)]

use crate::key::RadixKey;

use super::quicksort::quicksort;

/// Keys per buffer block (and per permuted slot).  Large enough that
/// the permutation moves cache-line-sized runs, small enough that the
/// 256 buffers stay modest (256 · 128 keys ≈ 256 KiB for u64 images).
pub const BLOCK: usize = 128;

/// Bucket fan-out per level: one 8-bit digit of the u64 radix image.
pub const BUCKETS: usize = 256;

/// Slices at or below this many keys are handed to
/// [`seq::quicksort`](crate::seq::quicksort) instead of partitioning
/// further (a bucket this small no longer amortises the 256-bucket
/// bookkeeping).
pub const FALLBACK_CUTOFF: usize = 512;

/// Bits per digit; [`BUCKETS`] = 2^DIGIT_BITS.
const DIGIT_BITS: u32 = 8;

/// Sort `a` ascending in place.
///
/// Entry point of the engine: allocates one [`Scratch`] (reused across
/// every recursion level) and recurses until buckets hit the quicksort
/// fallback.  O(n) auxiliary space in the buffers, independent of
/// recursion depth; depth is bounded by the 8 digits of the image.
///
/// Prefix-image domains (`K::IMAGE_EXACT == false`) finish with one
/// tie-break pass: the recursion orders the array by image, leaving
/// equal-image keys contiguous, and
/// [`seq::break_image_ties`](super::break_image_ties) re-sorts each
/// such run by the full `Ord` order (the quicksort fallback already
/// compares full keys, so its runs are merely re-verified).
pub fn ipssort<K: RadixKey>(a: &mut [K]) {
    if a.len() <= FALLBACK_CUTOFF {
        quicksort(a);
        return;
    }
    let mut scratch = Scratch::new();
    sort_rec(a, &mut scratch);
    super::break_image_ties(a);
}

/// Reusable per-sort working memory: the 256 partial-block buffers, the
/// flushed-block tag list, and the permutation's destination/visited
/// tables plus spare block.  One instance serves the whole recursion —
/// every phase drains what it borrowed before the recursion descends.
struct Scratch<K> {
    /// Partial buffer per bucket, each holding < [`BLOCK`] keys.
    buffers: Vec<Vec<K>>,
    /// Bucket tag of each flushed block, in flush (= scan) order.
    tags: Vec<u8>,
    /// Destination slot of each flushed block (filled by the permutation).
    dest: Vec<u32>,
    /// Visited marks for the permutation's cycle walk.
    done: Vec<bool>,
    /// The spare block the cycle walk carries.
    carried: Vec<K>,
}

impl<K: RadixKey> Scratch<K> {
    fn new() -> Self {
        Scratch {
            buffers: (0..BUCKETS).map(|_| Vec::with_capacity(BLOCK)).collect(),
            tags: Vec::new(),
            dest: Vec::new(),
            done: Vec::new(),
            carried: Vec::with_capacity(BLOCK),
        }
    }
}

/// Recursive driver: plan the digit, run the three data-movement
/// phases, then recurse into every bucket larger than the fallback.
fn sort_rec<K: RadixKey>(a: &mut [K], sc: &mut Scratch<K>) {
    if a.len() <= FALLBACK_CUTOFF {
        quicksort(a);
        return;
    }
    let Some(digit) = plan_digit(a) else {
        // All images equal ⇒ for exact images all keys are equal and
        // the slice is sorted; for prefix images (IMAGE_EXACT = false)
        // the keys may still differ past the prefix, but they form one
        // contiguous equal-image run that the top-level tie-break pass
        // in `ipssort` re-sorts by full `Ord`.
        return;
    };
    let shift = digit * DIGIT_BITS;
    let (counts, flushed) = classify(a, shift, sc);
    let full_blocks = full_block_counts(&counts, sc);
    debug_assert_eq!(full_blocks.iter().sum::<usize>() * BLOCK, flushed);
    permute_blocks(a, sc, &full_blocks);
    let starts = cleanup(a, sc, &counts, &full_blocks);
    for d in 0..BUCKETS {
        if counts[d] > 1 {
            // Within a bucket all keys share every byte from `digit`
            // up (higher bytes were already common before this level),
            // so the sub-call's plan_digit finds a strictly lower
            // digit: depth ≤ 8 levels.
            sort_rec(&mut a[starts[d]..starts[d] + counts[d]], sc);
        }
    }
}

/// Phase 1 — pick the partitioning digit: the most-significant byte in
/// which the radix images of `a` differ (`0` = least-significant byte).
/// `None` iff all images (hence all keys) are equal.
fn plan_digit<K: RadixKey>(a: &[K]) -> Option<u32> {
    let first = a.first()?.radix_image();
    let (mut min, mut max) = (first, first);
    for k in &a[1..] {
        let im = k.radix_image();
        min = min.min(im);
        max = max.max(im);
    }
    let diff = min ^ max;
    if diff == 0 {
        None
    } else {
        Some((63 - diff.leading_zeros()) / DIGIT_BITS)
    }
}

/// Phase 2 — classification.  Scans `a` once; each key goes into its
/// bucket's buffer, and a buffer reaching [`BLOCK`] keys is flushed
/// back into `a` at the write frontier, its bucket appended to
/// `sc.tags`.  Returns the per-bucket counts and the flushed length
/// (`sc.tags.len() * BLOCK`); keys beyond it live in `sc.buffers` and
/// `a[flushed..]` is stale.
///
/// In-place safety: after key `i` is consumed, flushed + buffered
/// = i + 1; a flush needs `BLOCK` buffered keys, so its target
/// `[write, write + BLOCK)` ends at or before `i + 1` — only
/// already-consumed slots are overwritten.
fn classify<K: RadixKey>(
    a: &mut [K],
    shift: u32,
    sc: &mut Scratch<K>,
) -> ([usize; BUCKETS], usize) {
    debug_assert!(sc.tags.is_empty());
    debug_assert!(sc.buffers.iter().all(|b| b.is_empty()));
    let mut counts = [0usize; BUCKETS];
    let mut write = 0usize;
    for i in 0..a.len() {
        let k = a[i];
        let d = ((k.radix_image() >> shift) & (BUCKETS as u64 - 1)) as usize;
        counts[d] += 1;
        let buf = &mut sc.buffers[d];
        buf.push(k);
        if buf.len() == BLOCK {
            debug_assert!(write + BLOCK <= i + 1);
            a[write..write + BLOCK].copy_from_slice(buf);
            buf.clear();
            sc.tags.push(d as u8);
            write += BLOCK;
        }
    }
    (counts, write)
}

/// Full (flushed) blocks per bucket: the bucket count minus its
/// buffered remainder, in blocks.
fn full_block_counts<K>(counts: &[usize; BUCKETS], sc: &Scratch<K>) -> [usize; BUCKETS] {
    let mut full = [0usize; BUCKETS];
    for d in 0..BUCKETS {
        debug_assert_eq!((counts[d] - sc.buffers[d].len()) % BLOCK, 0);
        full[d] = (counts[d] - sc.buffers[d].len()) / BLOCK;
    }
    full
}

/// Phase 3 — in-place block permutation.  The `j`-th flushed block of
/// bucket `d` (flush order) moves to slot `first_slot_d + j`, where
/// `first_slot` is the exclusive prefix sum of `full_blocks`; afterwards
/// each bucket's full blocks are contiguous and buckets are in order.
/// Cycle-following with the one spare block in `sc.carried`: the block
/// held in hand is swapped into its destination slot, picking up that
/// slot's old block, until the cycle closes.
fn permute_blocks<K: RadixKey>(a: &mut [K], sc: &mut Scratch<K>, full_blocks: &[usize; BUCKETS]) {
    let nslots = sc.tags.len();
    let mut cursor = [0usize; BUCKETS];
    let mut acc = 0usize;
    for d in 0..BUCKETS {
        cursor[d] = acc;
        acc += full_blocks[d];
    }
    debug_assert_eq!(acc, nslots);
    sc.dest.clear();
    for &t in &sc.tags {
        sc.dest.push(cursor[t as usize] as u32);
        cursor[t as usize] += 1;
    }
    sc.done.clear();
    sc.done.resize(nslots, false);
    for start in 0..nslots {
        if sc.done[start] {
            continue;
        }
        sc.done[start] = true;
        let mut pos = sc.dest[start] as usize;
        if pos == start {
            continue;
        }
        // `carried` holds the block destined for `pos` throughout.
        sc.carried.clear();
        sc.carried.extend_from_slice(&a[start * BLOCK..(start + 1) * BLOCK]);
        while pos != start {
            sc.carried.swap_with_slice(&mut a[pos * BLOCK..(pos + 1) * BLOCK]);
            sc.done[pos] = true;
            pos = sc.dest[pos] as usize;
        }
        a[start * BLOCK..(start + 1) * BLOCK].copy_from_slice(&sc.carried);
    }
}

/// Phase 4 — cleanup.  Computes the exact bucket boundaries
/// (`starts[d] = Σ_{e<d} counts[e]`), shifts each bucket's full-block
/// run from its permuted position onto `starts[d]`, and drains the
/// partial buffer into the tail gap, emptying the scratch for the next
/// level.  Returns `starts`.
///
/// Runs shift only rightward (by the partial keys of lower buckets) and
/// are processed from the highest bucket down, so every write lands at
/// or beyond the end of each still-unmoved lower run, and each source
/// is still intact when read (`copy_within` handles the self-overlap).
fn cleanup<K: RadixKey>(
    a: &mut [K],
    sc: &mut Scratch<K>,
    counts: &[usize; BUCKETS],
    full_blocks: &[usize; BUCKETS],
) -> [usize; BUCKETS] {
    let mut starts = [0usize; BUCKETS];
    let mut acc = 0usize;
    for d in 0..BUCKETS {
        starts[d] = acc;
        acc += counts[d];
    }
    debug_assert_eq!(acc, a.len());
    let mut run_start = [0usize; BUCKETS];
    let mut slot_acc = 0usize;
    for d in 0..BUCKETS {
        run_start[d] = slot_acc * BLOCK;
        slot_acc += full_blocks[d];
    }
    for d in (0..BUCKETS).rev() {
        let len = full_blocks[d] * BLOCK;
        if len > 0 && run_start[d] != starts[d] {
            debug_assert!(run_start[d] < starts[d]);
            a.copy_within(run_start[d]..run_start[d] + len, starts[d]);
        }
    }
    for d in 0..BUCKETS {
        let buf = &mut sc.buffers[d];
        if !buf.is_empty() {
            let at = starts[d] + full_blocks[d] * BLOCK;
            a[at..at + buf.len()].copy_from_slice(buf);
            buf.clear();
        }
    }
    sc.tags.clear();
    starts
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::{arb_keys, check, multiset_sig};
    use crate::util::rng::SplitMix64;

    /// Random keys long enough to exercise multi-block, multi-level
    /// behaviour (several full blocks and partial remainders).
    fn arb_big(rng: &mut SplitMix64) -> Vec<i32> {
        arb_keys(rng, FALLBACK_CUTOFF + 1, 6000, i32::MIN / 2, i32::MAX / 2)
    }

    fn digit_of<K: RadixKey>(k: K, shift: u32) -> usize {
        ((k.radix_image() >> shift) & (BUCKETS as u64 - 1)) as usize
    }

    #[test]
    fn plan_digit_finds_highest_distinguishing_byte() {
        // Differ in image byte 1 only (values 0x100 apart).
        assert_eq!(plan_digit(&[0x100u64, 0x2FFu64]), Some(1));
        // Byte 7 differs.
        assert_eq!(plan_digit(&[0u64, 1u64 << 60]), Some(7));
        // Signed keys: the biased i32 image puts -1 at 0x7FFF_FFFF and
        // 0 at 0x8000_0000, so the top image byte distinguishes them.
        assert_eq!(plan_digit(&[-1i32, 0i32]), Some(3));
        // All equal ⇒ None.
        assert_eq!(plan_digit(&[7u64; 100]), None);
        assert_eq!(plan_digit(&[] as &[u64]), None);
    }

    #[test]
    fn classification_counts_sum_and_respect_digit_order() {
        check("ips-classify", |rng| {
            let mut a = arb_big(rng);
            let before = multiset_sig(a.iter().copied());
            let shift = plan_digit(&a).unwrap_or(0) * DIGIT_BITS;
            let expected: Vec<usize> = {
                let mut c = vec![0usize; BUCKETS];
                for &k in &a {
                    c[digit_of(k, shift)] += 1;
                }
                c
            };
            let mut sc = Scratch::new();
            let (counts, flushed) = classify(&mut a, shift, &mut sc);
            // Counts are exact per-digit histograms and sum to n.
            assert_eq!(counts.to_vec(), expected);
            assert_eq!(counts.iter().sum::<usize>(), a.len());
            // Flushed prefix + buffered remainders partition the input.
            assert_eq!(flushed, sc.tags.len() * BLOCK);
            let buffered: usize = sc.buffers.iter().map(|b| b.len()).sum();
            assert_eq!(flushed + buffered, a.len());
            // Every flushed block is digit-pure and matches its tag;
            // every buffer holds only its own bucket's keys.
            for (s, &t) in sc.tags.iter().enumerate() {
                for &k in &a[s * BLOCK..(s + 1) * BLOCK] {
                    assert_eq!(digit_of(k, shift), t as usize);
                }
            }
            for (d, buf) in sc.buffers.iter().enumerate() {
                assert!(buf.len() < BLOCK);
                for &k in buf {
                    assert_eq!(digit_of(k, shift), d);
                }
            }
            // Nothing lost or invented: flushed ∪ buffers is the input.
            let after = multiset_sig(
                a[..flushed].iter().copied().chain(sc.buffers.iter().flatten().copied()),
            );
            assert_eq!(before, after);
        });
    }

    #[test]
    fn permutation_is_a_permutation_in_bucket_order() {
        check("ips-permute", |rng| {
            let mut a = arb_big(rng);
            let shift = plan_digit(&a).unwrap_or(0) * DIGIT_BITS;
            let mut sc = Scratch::new();
            let (counts, flushed) = classify(&mut a, shift, &mut sc);
            let full = full_block_counts(&counts, &sc);
            let before = multiset_sig(a[..flushed].iter().copied());
            permute_blocks(&mut a, &mut sc, &full);
            // The flushed prefix is permuted, not altered.
            assert_eq!(before, multiset_sig(a[..flushed].iter().copied()));
            // Each bucket's full blocks are contiguous and digit-pure.
            let mut at = 0usize;
            for d in 0..BUCKETS {
                for &k in &a[at..at + full[d] * BLOCK] {
                    assert_eq!(digit_of(k, shift), d);
                }
                at += full[d] * BLOCK;
            }
            assert_eq!(at, flushed);
        });
    }

    #[test]
    fn cleanup_aligns_every_bucket_boundary() {
        check("ips-cleanup", |rng| {
            let mut a = arb_big(rng);
            let before = multiset_sig(a.iter().copied());
            let shift = plan_digit(&a).unwrap_or(0) * DIGIT_BITS;
            let mut sc = Scratch::new();
            let (counts, _) = classify(&mut a, shift, &mut sc);
            let full = full_block_counts(&counts, &sc);
            permute_blocks(&mut a, &mut sc, &full);
            let starts = cleanup(&mut a, &mut sc, &counts, &full);
            // Bucket d occupies exactly [starts[d], starts[d]+counts[d])
            // and is digit-pure: boundary-aligned by construction.
            for d in 0..BUCKETS {
                assert_eq!(starts[d], counts[..d].iter().sum::<usize>());
                for &k in &a[starts[d]..starts[d] + counts[d]] {
                    assert_eq!(digit_of(k, shift), d);
                }
            }
            // The whole array is again a permutation of the input and
            // the scratch fully drained for the next level.
            assert_eq!(before, multiset_sig(a.iter().copied()));
            assert!(sc.tags.is_empty() && sc.buffers.iter().all(|b| b.is_empty()));
            // Once each small bucket is finished by the fallback, the
            // aligned buckets compose into the full sorted order.
            for d in 0..BUCKETS {
                quicksort(&mut a[starts[d]..starts[d] + counts[d]]);
            }
            assert!(a.windows(2).all(|w| w[0] <= w[1]));
        });
    }

    #[test]
    fn ipssort_matches_sort_unstable_on_random_i32() {
        check("ips-e2e-i32", |rng| {
            let mut a = arb_keys(rng, 0, 5000, i32::MIN, i32::MAX);
            let mut expect = a.clone();
            expect.sort_unstable();
            ipssort(&mut a);
            assert_eq!(a, expect);
        });
    }

    #[test]
    fn ipssort_handles_adversarial_shapes() {
        let shapes: Vec<Vec<u64>> = vec![
            vec![],
            vec![42],
            vec![7; 4096],
            (0..4096).map(|i| (i % 2) as u64 * u64::MAX).collect(),
            (0..4096).collect(),
            (0..4096).rev().collect(),
        ];
        for mut a in shapes {
            let mut expect = a.clone();
            expect.sort_unstable();
            ipssort(&mut a);
            assert_eq!(a, expect);
        }
    }

    #[test]
    fn ipssort_sorts_wide_domains() {
        check("ips-e2e-wide", |rng| {
            let n = 600 + rng.below(3000) as usize;
            let mut u: Vec<u64> = (0..n).map(|_| rng.next_u64()).collect();
            let mut expect = u.clone();
            expect.sort_unstable();
            ipssort(&mut u);
            assert_eq!(u, expect);

            let mut f: Vec<crate::key::F64> = (0..n)
                .map(|_| {
                    let x = (rng.next_u64() % 2_000_000) as f64 / 1000.0 - 1000.0;
                    crate::key::F64(x)
                })
                .collect();
            let mut expect: Vec<_> = f.clone();
            expect.sort_unstable();
            ipssort(&mut f);
            assert_eq!(f, expect);

            let mut r: Vec<crate::key::Record> = (0..n)
                .map(|_| crate::key::Record {
                    // Narrow key range forces duplicate keys with
                    // distinct payloads — image byte 0 must decide.
                    key: rng.below(64) as u32,
                    payload: rng.next_u64() as u32,
                })
                .collect();
            let mut expect = r.clone();
            expect.sort_unstable();
            ipssort(&mut r);
            assert_eq!(r, expect);
        });
    }

    #[test]
    fn ipssort_preserves_multisets() {
        check("ips-multiset", |rng| {
            let a = arb_big(rng);
            let before = multiset_sig(a.iter().copied());
            let mut sorted = a.clone();
            ipssort(&mut sorted);
            assert_eq!(before, multiset_sig(sorted.iter().copied()));
        });
    }

    #[test]
    fn small_slices_take_the_quicksort_fallback() {
        // ≤ FALLBACK_CUTOFF keys never build a Scratch; behaviourally
        // this is just "still sorts correctly at every tiny size".
        for n in [0usize, 1, 2, 3, BLOCK - 1, BLOCK, FALLBACK_CUTOFF] {
            let mut a: Vec<i32> = (0..n as i32).rev().collect();
            ipssort(&mut a);
            assert!(a.windows(2).all(|w| w[0] <= w[1]), "n={n}");
        }
    }

    #[test]
    fn equal_images_mean_equal_keys() {
        // Guard the injectivity assumption the all-equal short-circuit
        // relies on for *exact*-image domains: distinct records must
        // have distinct images, and image order must follow key order.
        // (`key::Str` is the deliberate exception — IMAGE_EXACT = false
        // — and is covered by the tie-break pass instead; see key.rs.)
        let a = crate::key::Record { key: 3, payload: 9 };
        let b = crate::key::Record { key: 3, payload: 10 };
        assert_ne!(a.radix_image(), b.radix_image());
        assert_eq!(a < b, a.radix_image() < b.radix_image());
    }

    #[test]
    fn prefix_image_all_equal_run_is_tie_broken() {
        // An input whose images are *all* equal but whose keys differ:
        // sort_rec's plan_digit short-circuit returns immediately, and
        // only the top-level tie-break pass can order it.
        use crate::key::Str;
        let mut rng = crate::util::rng::SplitMix64::new(0x7135);
        let mut a: Vec<Str> = (0..(FALLBACK_CUTOFF * 2))
            .map(|_| {
                let mut b = *b"sameprfx\0\0\0\0\0\0\0\0";
                for slot in b.iter_mut().skip(8).take((rng.next_u64() % 9) as usize) {
                    *slot = b'a' + (rng.next_u64() % 26) as u8;
                }
                Str(b)
            })
            .collect();
        let mut expect = a.clone();
        expect.sort_unstable();
        ipssort(&mut a);
        assert_eq!(a, expect);
    }
}
