//! Sequential merging: stable two-way merge and the p-way loser-tree
//! merge used by the Merging phase (Ph6) of both sorting algorithms.
//!
//! The paper charges `n lg q` for merging `q` lists of total size `n`
//! [49]; the loser tree achieves exactly `⌈lg q⌉` comparisons per emitted
//! key.  Stability across runs is by *run index*: when keys are equal the
//! run that arrived from the lower-numbered processor wins — precisely
//! the §5.1.1 requirement ("if the keys at the head of two sorted
//! sequences are equal the one received from processor i appears before
//! the one received from processor j, i < j").

use crate::key::Key;

/// Stable two-way merge of sorted `a` and `b` (ties favour `a`).
pub fn merge2<T: Copy + Ord>(a: &[T], b: &[T]) -> Vec<T> {
    let mut out = Vec::with_capacity(a.len() + b.len());
    let (mut i, mut j) = (0, 0);
    while i < a.len() && j < b.len() {
        if a[i] <= b[j] {
            out.push(a[i]);
            i += 1;
        } else {
            out.push(b[j]);
            j += 1;
        }
    }
    out.extend_from_slice(&a[i..]);
    out.extend_from_slice(&b[j..]);
    out
}

/// Stable q-way merge of sorted runs via a loser tree.
///
/// Runs are ordered: ties between heads resolve to the lower run index,
/// making the output stable with respect to run order.
pub fn multiway_merge<K: Key>(runs: &[Vec<K>]) -> Vec<K> {
    multiway_merge_slices(&runs.iter().map(|r| r.as_slice()).collect::<Vec<_>>())
}

/// Owned q-way merge: consumes the runs, reusing one of their buffers
/// when no real merging is required (zero or one non-empty run).  The
/// Ph6 hand-off uses this so a degenerate routing round — everything
/// from one sender — costs no extra copy at all.
pub fn multiway_merge_owned<K: Key>(mut runs: Vec<Vec<K>>) -> Vec<K> {
    runs.retain(|r| !r.is_empty());
    match runs.len() {
        0 => Vec::new(),
        1 => runs.pop().unwrap(),
        _ => multiway_merge(&runs),
    }
}

/// Slice-based variant (no ownership needed).
pub fn multiway_merge_slices<K: Key>(runs: &[&[K]]) -> Vec<K> {
    let q = runs.len();
    let total: usize = runs.iter().map(|r| r.len()).sum();
    match q {
        0 => return Vec::new(),
        1 => return runs[0].to_vec(),
        2 => return merge2(runs[0], runs[1]),
        _ => {}
    }

    let mut out = Vec::with_capacity(total);
    let mut tree = LoserTree::new(runs);
    while let Some(key) = tree.pop() {
        out.push(key);
    }
    out
}

/// A loser tree over `q` runs with *cached head keys*: each node stores
/// `(key, run)` so a pop replays one leaf-to-root path with `⌈lg q⌉`
/// cached-key comparisons and no indirection through the run slices.
///
/// Exhausted runs hold the sentinel `(K::max_key(), u32::MAX)`; a *real*
/// maximal key still wins against the sentinel because ties resolve to
/// the lower run index — no key value is reserved.
struct LoserTree<'a, K: Key> {
    runs: &'a [&'a [K]],
    cursors: Vec<usize>,
    /// Internal nodes `tree[1..k]` store losers; `tree[0]` the champion.
    tree: Vec<(K, u32)>,
    k: usize,
    remaining: usize,
}

#[inline]
fn sentinel<K: Key>() -> (K, u32) {
    (K::max_key(), u32::MAX)
}

#[inline]
fn beats<K: Key>(a: (K, u32), b: (K, u32)) -> bool {
    a.0 < b.0 || (a.0 == b.0 && a.1 < b.1)
}

impl<'a, K: Key> LoserTree<'a, K> {
    fn new(runs: &'a [&'a [K]]) -> Self {
        let q = runs.len();
        let k = q.next_power_of_two();
        let remaining = runs.iter().map(|r| r.len()).sum();
        let mut lt = LoserTree {
            runs,
            cursors: vec![0; q],
            tree: vec![sentinel::<K>(); k],
            k,
            remaining,
        };
        // Bottom-up tournament: winners bubble up, each internal node
        // stores its loser, the champion lands in tree[0].
        let mut winners = vec![sentinel::<K>(); 2 * k];
        for (i, slot) in winners[k..k + q].iter_mut().enumerate() {
            *slot = match runs[i].first() {
                Some(&key) => (key, i as u32),
                None => sentinel::<K>(),
            };
        }
        for node in (1..k).rev() {
            let (a, b) = (winners[2 * node], winners[2 * node + 1]);
            let (w, l) = if beats(a, b) { (a, b) } else { (b, a) };
            winners[node] = w;
            lt.tree[node] = l;
        }
        lt.tree[0] = winners[1];
        lt
    }

    /// Remove and return the smallest head across all runs.
    #[inline]
    fn pop(&mut self) -> Option<K> {
        if self.remaining == 0 {
            return None;
        }
        self.remaining -= 1;
        let (key, run) = self.tree[0];
        let run_idx = run as usize;
        // Refill the champion's leaf with its run's next key.
        self.cursors[run_idx] += 1;
        let mut winner = match self.runs[run_idx].get(self.cursors[run_idx]) {
            Some(&next) => (next, run),
            None => sentinel::<K>(),
        };
        // Replay the leaf-to-root path (⌈lg q⌉ cached-key comparisons).
        let mut node = (self.k + run_idx) / 2;
        while node >= 1 {
            if beats(self.tree[node], winner) {
                std::mem::swap(&mut winner, &mut self.tree[node]);
            }
            if node == 1 {
                break;
            }
            node /= 2;
        }
        self.tree[0] = winner;
        Some(key)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::{arb_keys, check};

    #[test]
    fn merge2_basic_and_stable_bias() {
        assert_eq!(merge2(&[1, 3], &[2, 4]), vec![1, 2, 3, 4]);
        assert_eq!(merge2(&[], &[1]), vec![1]);
        assert_eq!(merge2(&[2, 2], &[2]), vec![2, 2, 2]);
    }

    #[test]
    fn multiway_equals_flat_sort_property() {
        check("multiway-vs-sort", |rng| {
            let q = 1 + rng.below(9) as usize;
            let mut runs: Vec<Vec<i32>> = Vec::new();
            let mut all: Vec<i32> = Vec::new();
            for _ in 0..q {
                let mut r = arb_keys(rng, 0, 300, -100, 100);
                r.sort_unstable();
                all.extend_from_slice(&r);
                runs.push(r);
            }
            all.sort_unstable();
            assert_eq!(multiway_merge(&runs), all);
        });
    }

    #[test]
    fn multiway_handles_empty_runs() {
        let runs = vec![vec![], vec![5], vec![], vec![1, 9], vec![]];
        assert_eq!(multiway_merge(&runs), vec![1, 5, 9]);
        assert!(multiway_merge(&[]).is_empty());
        assert!(multiway_merge(&[vec![], vec![]]).is_empty());
    }

    #[test]
    fn multiway_is_stable_by_run_index() {
        // All runs hold the same key; a stable merge emits them in run
        // order.  Track provenance with distinguishable lengths.
        let runs: Vec<Vec<i32>> = vec![vec![7, 7], vec![7], vec![7, 7, 7]];
        let out = multiway_merge(&runs);
        assert_eq!(out, vec![7; 6]);
        // Stability is observable via the pair variant below.
        let runs: Vec<Vec<(i32, u32)>> = vec![
            vec![(7, 0), (7, 1)],
            vec![(7, 10)],
            vec![(7, 20), (8, 21)],
        ];
        // Simulate: merge keys only but verify winner selection order by
        // replaying with a manual 3-way walk using the loser tree rule.
        let flat = multiway_merge(&[
            runs[0].iter().map(|&(k, _)| k).collect(),
            runs[1].iter().map(|&(k, _)| k).collect(),
            runs[2].iter().map(|&(k, _)| k).collect(),
        ]);
        assert_eq!(flat, vec![7, 7, 7, 7, 8]);
    }

    #[test]
    fn q_not_power_of_two() {
        for q in [3usize, 5, 6, 7, 9, 13] {
            let runs: Vec<Vec<i32>> = (0..q).map(|i| vec![i as i32, (i + q) as i32]).collect();
            let mut expect: Vec<i32> = runs.iter().flatten().copied().collect();
            expect.sort_unstable();
            assert_eq!(multiway_merge(&runs), expect, "q={q}");
        }
    }

    #[test]
    fn single_long_run_is_identity() {
        let r: Vec<i32> = (0..1000).collect();
        assert_eq!(multiway_merge(&[r.clone()]), r);
    }

    #[test]
    fn owned_merge_matches_borrowed_and_reuses_single_run() {
        let runs = vec![vec![], vec![1, 4], vec![2, 3], vec![]];
        assert_eq!(multiway_merge_owned(runs.clone()), multiway_merge(&runs));
        // Single non-empty run: the buffer comes back as-is.
        let solo = vec![vec![], vec![7, 8, 9], vec![]];
        assert_eq!(multiway_merge_owned(solo), vec![7, 8, 9]);
        assert!(multiway_merge_owned(vec![vec![], vec![]]).is_empty());
    }
}
