//! LSD radix sort (the paper's `SORT_SEQ` integer variant, used by the
//! \[DSR\]/\[RSR\] implementations), generic over any [`RadixKey`].
//!
//! `K::RADIX_PASSES` 8-bit passes over the key's order-preserving
//! unsigned image (`radix_image`: the bias map `key ^ i32::MIN` for
//! `i32`, total-order bits for `f64`, the packed word for records),
//! counting sort per pass with a ping-pong buffer.  Stable (irrelevant
//! for bare keys but required by the tagged variant used in tests),
//! linear time; the charge policy prices it at 15 comparison-equivalents
//! per key (ops.rs).
//!
//! Prefix-image domains (`K::IMAGE_EXACT == false`, e.g. `key::Str`)
//! get one extra tie-break pass after the counting passes: the passes
//! leave equal-image keys contiguous, and [`seq::break_image_ties`]
//! re-sorts each such run by the full `Ord` order.
//!
//! [`seq::break_image_ties`]: super::break_image_ties

use crate::key::RadixKey;

/// Sort `a` ascending in place (allocates one scratch buffer).
pub fn radixsort<K: RadixKey>(a: &mut [K]) {
    let n = a.len();
    if n <= 1 {
        return;
    }
    let mut scratch: Vec<K> = vec![a[0]; n];
    let mut src_is_a = true;
    for pass in 0..K::RADIX_PASSES {
        let shift = pass * 8;
        let (src, dst): (&[K], &mut [K]) = if src_is_a {
            (&a[..], &mut scratch[..])
        } else {
            (&scratch[..], &mut a[..])
        };
        counting_pass(src, dst, shift);
        src_is_a = !src_is_a;
    }
    if !src_is_a {
        a.copy_from_slice(&scratch);
    }
    super::break_image_ties(a);
}

/// One stable counting pass on byte `shift/8` of the radix image.
fn counting_pass<K: RadixKey>(src: &[K], dst: &mut [K], shift: u32) {
    let mut counts = [0usize; 256];
    for &k in src {
        counts[((k.radix_image() >> shift) & 0xFF) as usize] += 1;
    }
    let mut offsets = [0usize; 256];
    let mut sum = 0usize;
    for (offset, &count) in offsets.iter_mut().zip(counts.iter()) {
        *offset = sum;
        sum += count;
    }
    for &k in src {
        let b = ((k.radix_image() >> shift) & 0xFF) as usize;
        dst[offsets[b]] = k;
        offsets[b] += 1;
    }
}

/// Radix sort of `(key, payload)` pairs by key — used by tests asserting
/// the stability the paper's §5.1.1 duplicate handling relies on.
pub fn radixsort_pairs<K: RadixKey>(a: &mut [(K, u32)]) {
    let n = a.len();
    if n <= 1 {
        return;
    }
    let mut scratch: Vec<(K, u32)> = vec![a[0]; n];
    let mut src_is_a = true;
    for pass in 0..K::RADIX_PASSES {
        let shift = pass * 8;
        let (src, dst): (&[(K, u32)], &mut [(K, u32)]) = if src_is_a {
            (&a[..], &mut scratch[..])
        } else {
            (&scratch[..], &mut a[..])
        };
        let mut counts = [0usize; 256];
        for &(k, _) in src {
            counts[((k.radix_image() >> shift) & 0xFF) as usize] += 1;
        }
        let mut offsets = [0usize; 256];
        let mut sum = 0usize;
        for (offset, &count) in offsets.iter_mut().zip(counts.iter()) {
            *offset = sum;
            sum += count;
        }
        for &it in src {
            let b = ((it.0.radix_image() >> shift) & 0xFF) as usize;
            dst[offsets[b]] = it;
            offsets[b] += 1;
        }
        src_is_a = !src_is_a;
    }
    if !src_is_a {
        a.copy_from_slice(&scratch);
    }
    if !K::IMAGE_EXACT {
        // Tie-break for prefix images, preserving stability: a *stable*
        // by-key sort of each equal-image run keeps equal keys in the
        // pass-stable payload order.
        let mut i = 0;
        while i < n {
            let img = a[i].0.radix_image();
            let mut j = i + 1;
            while j < n && a[j].0.radix_image() == img {
                j += 1;
            }
            if j - i > 1 {
                a[i..j].sort_by(|x, y| x.0.cmp(&y.0));
            }
            i = j;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::key::{F64, Record};
    use crate::util::check::{arb_keys, check};

    #[test]
    fn sorts_random_inputs_property() {
        check("radixsort-random", |rng| {
            let mut keys = arb_keys(rng, 0, 3000, i32::MIN, i32::MAX);
            let mut expect = keys.clone();
            expect.sort_unstable();
            radixsort(&mut keys);
            assert_eq!(keys, expect);
        });
    }

    #[test]
    fn sorts_negative_positive_mix() {
        let mut a = vec![-1, 1, 0, i32::MIN, i32::MAX, -256, 256, -257, 255];
        let mut expect = a.clone();
        expect.sort_unstable();
        radixsort(&mut a);
        assert_eq!(a, expect);
    }

    #[test]
    fn empty_and_singleton() {
        let mut e: Vec<i32> = vec![];
        radixsort(&mut e);
        assert!(e.is_empty());
        let mut s = vec![-5];
        radixsort(&mut s);
        assert_eq!(s, vec![-5]);
    }

    #[test]
    fn duplicate_heavy_property() {
        check("radixsort-dups", |rng| {
            let mut keys = arb_keys(rng, 0, 3000, -2, 2);
            let mut expect = keys.clone();
            expect.sort_unstable();
            radixsort(&mut keys);
            assert_eq!(keys, expect);
        });
    }

    #[test]
    fn sorts_u64_and_f64_domains_property() {
        check("radixsort-wide-domains", |rng| {
            let mut u: Vec<u64> = (0..500).map(|_| rng.next_u64()).collect();
            let mut expect_u = u.clone();
            expect_u.sort_unstable();
            radixsort(&mut u);
            assert_eq!(u, expect_u);

            // Arbitrary bit patterns include NaNs, ±0, subnormals — the
            // total-order image must sort them all deterministically.
            let mut f: Vec<F64> = (0..500).map(|_| F64(f64::from_bits(rng.next_u64()))).collect();
            let mut expect_f = f.clone();
            expect_f.sort_unstable();
            radixsort(&mut f);
            assert_eq!(f, expect_f);
        });
    }

    #[test]
    fn sorts_records_lexicographically() {
        let mut recs = vec![
            Record { key: 2, payload: 0 },
            Record { key: 1, payload: 9 },
            Record { key: 2, payload: 7 },
            Record { key: 0, payload: 3 },
        ];
        let mut expect = recs.clone();
        expect.sort_unstable();
        radixsort(&mut recs);
        assert_eq!(recs, expect);
    }

    #[test]
    fn pairs_sort_is_stable() {
        check("radixsort-pairs-stable", |rng| {
            let keys = arb_keys(rng, 0, 500, -4, 4);
            let mut pairs: Vec<(i32, u32)> =
                keys.iter().enumerate().map(|(i, &k)| (k, i as u32)).collect();
            let mut expect = pairs.clone();
            expect.sort_by_key(|&(k, i)| (k, i)); // stable == payload order
            radixsort_pairs(&mut pairs);
            assert_eq!(pairs, expect);
        });
    }
}
