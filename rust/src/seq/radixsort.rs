//! LSD radix sort for 32-bit integer keys (the paper's `SORT_SEQ` integer
//! variant, used by the [DSR]/[RSR] implementations).
//!
//! Four 8-bit passes over a bias-mapped unsigned image of the key
//! (`key ^ i32::MIN` orders identically to signed order), counting sort
//! per pass with a ping-pong buffer.  Stable (irrelevant for bare keys but
//! required by the tagged variant used in tests), linear time; the charge
//! policy prices it at 15 comparisons-equivalents per key (ops.rs).

/// Sort `a` ascending in place (allocates one scratch buffer).
pub fn radixsort(a: &mut Vec<i32>) {
    let n = a.len();
    if n <= 1 {
        return;
    }
    let mut scratch: Vec<i32> = vec![0; n];
    let mut src_is_a = true;
    for pass in 0..4 {
        let shift = pass * 8;
        let (src, dst): (&[i32], &mut [i32]) = if src_is_a {
            (&a[..], &mut scratch[..])
        } else {
            (&scratch[..], &mut a[..])
        };
        if !counting_pass(src, dst, shift) {
            // Pass was a no-op permutation (single bucket): data already
            // placed in dst by the copy inside counting_pass.
        }
        src_is_a = !src_is_a;
    }
    if !src_is_a {
        a.copy_from_slice(&scratch);
    }
}

/// One stable counting pass on byte `shift/8`; returns false if all keys
/// share the byte (still copies src→dst to keep the ping-pong invariant).
fn counting_pass(src: &[i32], dst: &mut [i32], shift: u32) -> bool {
    let mut counts = [0usize; 256];
    for &k in src {
        let b = (biased(k) >> shift) & 0xFF;
        counts[b as usize] += 1;
    }
    let distinct = counts.iter().filter(|&&c| c > 0).count();
    let mut offsets = [0usize; 256];
    let mut sum = 0usize;
    for i in 0..256 {
        offsets[i] = sum;
        sum += counts[i];
    }
    for &k in src {
        let b = ((biased(k) >> shift) & 0xFF) as usize;
        dst[offsets[b]] = k;
        offsets[b] += 1;
    }
    distinct > 1
}

/// Map a signed key to an unsigned image with identical ordering.
#[inline]
fn biased(k: i32) -> u32 {
    (k as u32) ^ 0x8000_0000
}

/// Radix sort of `(key, payload)` pairs by key — used by tests asserting
/// the stability the paper's §5.1.1 duplicate handling relies on.
pub fn radixsort_pairs(a: &mut Vec<(i32, u32)>) {
    let n = a.len();
    if n <= 1 {
        return;
    }
    let mut scratch: Vec<(i32, u32)> = vec![(0, 0); n];
    let mut src_is_a = true;
    for pass in 0..4 {
        let shift = pass * 8;
        let (src, dst): (&[(i32, u32)], &mut [(i32, u32)]) = if src_is_a {
            (&a[..], &mut scratch[..])
        } else {
            (&scratch[..], &mut a[..])
        };
        let mut counts = [0usize; 256];
        for &(k, _) in src {
            counts[((biased(k) >> shift) & 0xFF) as usize] += 1;
        }
        let mut offsets = [0usize; 256];
        let mut sum = 0usize;
        for i in 0..256 {
            offsets[i] = sum;
            sum += counts[i];
        }
        for &it in src {
            let b = ((biased(it.0) >> shift) & 0xFF) as usize;
            dst[offsets[b]] = it;
            offsets[b] += 1;
        }
        src_is_a = !src_is_a;
    }
    if !src_is_a {
        a.copy_from_slice(&scratch);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::{arb_keys, check};

    #[test]
    fn sorts_random_inputs_property() {
        check("radixsort-random", |rng| {
            let mut keys = arb_keys(rng, 0, 3000, i32::MIN, i32::MAX);
            let mut expect = keys.clone();
            expect.sort_unstable();
            radixsort(&mut keys);
            assert_eq!(keys, expect);
        });
    }

    #[test]
    fn sorts_negative_positive_mix() {
        let mut a = vec![-1, 1, 0, i32::MIN, i32::MAX, -256, 256, -257, 255];
        let mut expect = a.clone();
        expect.sort_unstable();
        radixsort(&mut a);
        assert_eq!(a, expect);
    }

    #[test]
    fn empty_and_singleton() {
        let mut e: Vec<i32> = vec![];
        radixsort(&mut e);
        assert!(e.is_empty());
        let mut s = vec![-5];
        radixsort(&mut s);
        assert_eq!(s, vec![-5]);
    }

    #[test]
    fn duplicate_heavy_property() {
        check("radixsort-dups", |rng| {
            let mut keys = arb_keys(rng, 0, 3000, -2, 2);
            let mut expect = keys.clone();
            expect.sort_unstable();
            radixsort(&mut keys);
            assert_eq!(keys, expect);
        });
    }

    #[test]
    fn pairs_sort_is_stable() {
        check("radixsort-pairs-stable", |rng| {
            let keys = arb_keys(rng, 0, 500, -4, 4);
            let mut pairs: Vec<(i32, u32)> =
                keys.iter().enumerate().map(|(i, &k)| (k, i as u32)).collect();
            let mut expect = pairs.clone();
            expect.sort_by_key(|&(k, i)| (k, i)); // stable == payload order
            radixsort_pairs(&mut pairs);
            assert_eq!(pairs, expect);
        });
    }
}
