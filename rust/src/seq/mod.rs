//! Sequential substrates (DESIGN.md §4.2): the `SORT_SEQ` backends, the
//! merge kernels and binary searches the BSP algorithms run per
//! processor, plus the paper's §1.1 operation-charging policy.

pub mod ips;
pub mod merge;
pub mod ops;
pub mod quicksort;
pub mod radixsort;
pub mod search;

use crate::key::{Key, RadixKey};

pub use ips::ipssort;
pub use merge::{merge2, multiway_merge, multiway_merge_owned, multiway_merge_slices};
pub use quicksort::quicksort;
pub use radixsort::radixsort;

/// Re-sort every maximal run of equal-image keys by the full `Ord`
/// order — the tie-break pass for prefix-image domains
/// (`K::IMAGE_EXACT == false`, e.g. `key::Str`).
///
/// Both radix engines order the array by `radix_image`, so after their
/// passes equal-image keys sit in one contiguous run; for an exact
/// image those keys are equal and this is a no-op (the engines skip the
/// scan entirely), for a prefix image each run still needs its
/// secondary comparison on the bytes the image dropped.
pub fn break_image_ties<K: RadixKey>(a: &mut [K]) {
    if K::IMAGE_EXACT {
        return;
    }
    let n = a.len();
    let mut i = 0;
    while i < n {
        let img = a[i].radix_image();
        let mut j = i + 1;
        while j < n && a[j].radix_image() == img {
            j += 1;
        }
        if j - i > 1 {
            a[i..j].sort_unstable();
        }
        i = j;
    }
}

/// Which sequential sorting backend a variant uses.
///
/// The paper studies `[.SQ]` (quicksort) and `[.SR]` (radixsort); `Ips`
/// (the in-place block-partitioning MSD radix engine, `seq::ips`) and
/// `Xla` (the AOT-compiled Pallas bitonic network run through PJRT,
/// runtime::XlaSort) are this repo's additions.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum SeqSortKind {
    Quick,
    Radix,
    Ips,
    Xla,
}

impl SeqSortKind {
    /// One-letter suffix used in variant names (\[DSQ\], \[DSR\],
    /// \[DSI\], \[DSX\]).
    pub fn suffix(&self) -> char {
        match self {
            SeqSortKind::Quick => 'Q',
            SeqSortKind::Radix => 'R',
            SeqSortKind::Ips => 'I',
            SeqSortKind::Xla => 'X',
        }
    }

    /// The charge (comparisons) for sorting `n` keys with this backend.
    pub fn charge(&self, n: usize) -> f64 {
        match self {
            SeqSortKind::Quick => ops::sort_charge(n),
            SeqSortKind::Radix => ops::radix_charge(n),
            // Kind-level charge prices the 4-digit (32-bit) reference
            // image; `IpsSorter::charge` scales by the domain's actual
            // pass count, exactly like the Radix pair above.
            SeqSortKind::Ips => ops::ips_charge(n),
            // The oblivious network performs n lg^2 n / 2 compare-
            // exchanges; on the T3D model we still charge its *work* —
            // the backend is for the TPU path where the VPU amortizes it.
            SeqSortKind::Xla => {
                let lg = crate::util::lg(n as f64);
                n as f64 * lg * (lg + 1.0) / 4.0
            }
        }
    }
}

/// A sequential sort backend usable inside a BSP processor, generic over
/// the key domain (default `i32`, so `&dyn SeqSorter` keeps meaning the
/// paper's integer backends — the XLA sorter implements exactly that).
pub trait SeqSorter<K: Key = i32>: Sync {
    /// Sort `keys` ascending in place.
    fn sort(&self, keys: &mut Vec<K>);
    /// Charged operations for sorting `n` keys (analytic, §1.1 policy).
    fn charge(&self, n: usize) -> f64;
    fn name(&self) -> &'static str;
}

/// Quicksort backend ([.SQ] variants) — any [`Key`] domain.
pub struct QuickSorter;

impl<K: Key> SeqSorter<K> for QuickSorter {
    fn sort(&self, keys: &mut Vec<K>) {
        quicksort::quicksort(keys);
    }
    fn charge(&self, n: usize) -> f64 {
        ops::sort_charge(n)
    }
    fn name(&self) -> &'static str {
        "quicksort"
    }
}

/// Radixsort backend ([.SR] variants) — domains with a radix image.
pub struct RadixSorter;

impl<K: RadixKey> SeqSorter<K> for RadixSorter {
    fn sort(&self, keys: &mut Vec<K>) {
        radixsort::radixsort(keys);
    }
    fn charge(&self, n: usize) -> f64 {
        // `radix_charge` calibrates the paper's 4-pass 32-bit sort
        // (15 ops/key, Table 6); wider domains run `K::RADIX_PASSES`
        // passes of the same counting kernel, so the charge scales
        // linearly in the pass count (×1 exactly for `i32`).
        ops::radix_charge(n) * (K::RADIX_PASSES as f64 / 4.0)
    }
    fn name(&self) -> &'static str {
        "radixsort"
    }
}

/// In-place block-partitioning MSD radix backend ([.SI] variants) —
/// domains with a radix image (see [`ips`]).
pub struct IpsSorter;

impl<K: RadixKey> SeqSorter<K> for IpsSorter {
    fn sort(&self, keys: &mut Vec<K>) {
        ips::ipssort(keys);
    }
    fn charge(&self, n: usize) -> f64 {
        // Unlike LSD radix, the MSD recursion depth tracks the
        // distinguishing prefix (≈ lg n bits), not the image width;
        // the domain's pass count only caps it (`ops::ips_levels`).
        ops::ips_charge_for(n, K::RADIX_PASSES)
    }
    fn name(&self) -> &'static str {
        "ipssort"
    }
}

/// Obtain a boxed backend for a kind (Xla requires the runtime and is
/// constructed in `runtime::xla_sort`).
pub fn backend<K: RadixKey>(kind: SeqSortKind) -> Box<dyn SeqSorter<K>> {
    match kind {
        SeqSortKind::Quick => Box::new(QuickSorter),
        SeqSortKind::Radix => Box::new(RadixSorter),
        SeqSortKind::Ips => Box::new(IpsSorter),
        SeqSortKind::Xla => panic!("XlaSort requires runtime::xla_sort::XlaSorter::new()"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backends_sort_correctly() {
        for kind in [SeqSortKind::Quick, SeqSortKind::Radix, SeqSortKind::Ips] {
            let b = backend(kind);
            let mut keys = vec![5, -3, 9, 0, 5, -3];
            b.sort(&mut keys);
            assert_eq!(keys, vec![-3, -3, 0, 5, 5, 9], "{}", b.name());
            assert!(b.charge(1024) > 0.0);
        }
    }

    #[test]
    fn ips_charge_caps_levels_at_the_domain_width() {
        // At 1024 keys the distinguishing prefix is 10 bits → 2 levels
        // on every domain with ≥ 2 digits; the i32/u64 charges agree
        // (LSD radix, by contrast, doubles from 4 to 8 passes).
        let i32_charge = SeqSorter::<i32>::charge(&IpsSorter, 1024);
        let u64_charge = SeqSorter::<u64>::charge(&IpsSorter, 1024);
        assert_eq!(i32_charge, ops::ips_charge_for(1024, 4));
        assert_eq!(i32_charge, u64_charge);
    }

    #[test]
    fn radix_charge_scales_with_pass_count() {
        // 8-pass domains (u64/f64/records) cost twice the 4-pass i32
        // calibration; i32 itself stays exactly at the Table 6 rate.
        let i32_charge = SeqSorter::<i32>::charge(&RadixSorter, 1024);
        let u64_charge = SeqSorter::<u64>::charge(&RadixSorter, 1024);
        assert!((i32_charge - ops::radix_charge(1024)).abs() < 1e-9);
        assert!((u64_charge - 2.0 * i32_charge).abs() < 1e-9);
    }

    #[test]
    fn suffixes() {
        assert_eq!(SeqSortKind::Quick.suffix(), 'Q');
        assert_eq!(SeqSortKind::Radix.suffix(), 'R');
        assert_eq!(SeqSortKind::Ips.suffix(), 'I');
        assert_eq!(SeqSortKind::Xla.suffix(), 'X');
    }
}
