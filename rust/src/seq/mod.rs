//! Sequential substrates (DESIGN.md §4.2): the `SORT_SEQ` backends, the
//! merge kernels and binary searches the BSP algorithms run per
//! processor, plus the paper's §1.1 operation-charging policy.

pub mod merge;
pub mod ops;
pub mod quicksort;
pub mod radixsort;
pub mod search;

pub use merge::{merge2, multiway_merge, multiway_merge_owned, multiway_merge_slices};
pub use quicksort::quicksort;
pub use radixsort::radixsort;

/// Which sequential sorting backend a variant uses.
///
/// The paper studies `[.SQ]` (quicksort) and `[.SR]` (radixsort); `Xla`
/// is this repo's addition — the AOT-compiled Pallas bitonic network run
/// through PJRT (runtime::XlaSort), exercised by examples and tests.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum SeqSortKind {
    Quick,
    Radix,
    Xla,
}

impl SeqSortKind {
    /// One-letter suffix used in variant names ([DSQ], [DSR], [DSX]).
    pub fn suffix(&self) -> char {
        match self {
            SeqSortKind::Quick => 'Q',
            SeqSortKind::Radix => 'R',
            SeqSortKind::Xla => 'X',
        }
    }

    /// The charge (comparisons) for sorting `n` keys with this backend.
    pub fn charge(&self, n: usize) -> f64 {
        match self {
            SeqSortKind::Quick => ops::sort_charge(n),
            SeqSortKind::Radix => ops::radix_charge(n),
            // The oblivious network performs n lg^2 n / 2 compare-
            // exchanges; on the T3D model we still charge its *work* —
            // the backend is for the TPU path where the VPU amortizes it.
            SeqSortKind::Xla => {
                let lg = crate::util::lg(n as f64);
                n as f64 * lg * (lg + 1.0) / 4.0
            }
        }
    }
}

/// A sequential sort backend usable inside a BSP processor.
pub trait SeqSorter: Sync {
    /// Sort `keys` ascending in place.
    fn sort(&self, keys: &mut Vec<i32>);
    /// Charged operations for sorting `n` keys (analytic, §1.1 policy).
    fn charge(&self, n: usize) -> f64;
    fn name(&self) -> &'static str;
}

/// Quicksort backend ([.SQ] variants).
pub struct QuickSorter;

impl SeqSorter for QuickSorter {
    fn sort(&self, keys: &mut Vec<i32>) {
        quicksort::quicksort(keys);
    }
    fn charge(&self, n: usize) -> f64 {
        ops::sort_charge(n)
    }
    fn name(&self) -> &'static str {
        "quicksort"
    }
}

/// Radixsort backend ([.SR] variants).
pub struct RadixSorter;

impl SeqSorter for RadixSorter {
    fn sort(&self, keys: &mut Vec<i32>) {
        radixsort::radixsort(keys);
    }
    fn charge(&self, n: usize) -> f64 {
        ops::radix_charge(n)
    }
    fn name(&self) -> &'static str {
        "radixsort"
    }
}

/// Obtain a boxed backend for a kind (Xla requires the runtime and is
/// constructed in `runtime::xla_sort`).
pub fn backend(kind: SeqSortKind) -> Box<dyn SeqSorter> {
    match kind {
        SeqSortKind::Quick => Box::new(QuickSorter),
        SeqSortKind::Radix => Box::new(RadixSorter),
        SeqSortKind::Xla => panic!("XlaSort requires runtime::xla_sort::XlaSorter::new()"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backends_sort_correctly() {
        for kind in [SeqSortKind::Quick, SeqSortKind::Radix] {
            let b = backend(kind);
            let mut keys = vec![5, -3, 9, 0, 5, -3];
            b.sort(&mut keys);
            assert_eq!(keys, vec![-3, -3, 0, 5, 5, 9], "{}", b.name());
            assert!(b.charge(1024) > 0.0);
        }
    }

    #[test]
    fn suffixes() {
        assert_eq!(SeqSortKind::Quick.suffix(), 'Q');
        assert_eq!(SeqSortKind::Radix.suffix(), 'R');
        assert_eq!(SeqSortKind::Xla.suffix(), 'X');
    }
}
