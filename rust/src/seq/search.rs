//! Binary search of splitters into locally sorted keys (step 9 of both
//! algorithms), with the §5.1.1 duplicate tie-break.
//!
//! A splitter is a tagged [`SampleRec`]; a local key at index `i` on
//! processor `pid` carries the *implicit* tag `(pid, i)`.  A local key is
//! "before" a splitter iff `(key, pid, i) < (s.key, s.proc, s.idx)`
//! lexicographically — this is what makes duplicate keys split exactly
//! and deterministically across processors without tagging the data.

use crate::bsp::msg::SampleRec;
use crate::key::Key;

/// Number of leading keys of `keys` (sorted ascending, owned by `pid`)
/// that order strictly before splitter `s` under the tagged comparison.
///
/// Equal keys resolve by `(proc, idx)`: all equal keys on processors
/// `< s.proc` go left; on `s.proc` itself, those with index `< s.idx`.
pub fn rank_before_splitter<K: Key>(keys: &[K], pid: usize, s: &SampleRec<K>) -> usize {
    let pid = pid as u32;
    // Find the boundary with a single binary search over the compound
    // order; the compound key of position i is (keys[i], pid, i), which
    // is nondecreasing in i because keys is sorted.
    let mut lo = 0usize;
    let mut hi = keys.len();
    while lo < hi {
        let mid = lo + (hi - lo) / 2;
        let local = (keys[mid], pid, mid as u32);
        if local < (s.key, s.proc, s.idx) {
            lo = mid + 1;
        } else {
            hi = mid;
        }
    }
    lo
}

/// Partition boundaries of `keys` induced by `splitters` (sorted by the
/// tagged order): returns `splitters.len() + 1` bucket extents as
/// cut positions `0 = c_0 <= c_1 <= ... <= c_p = keys.len()`.
pub fn partition_points<K: Key>(keys: &[K], pid: usize, splitters: &[SampleRec<K>]) -> Vec<usize> {
    let mut cuts = Vec::with_capacity(splitters.len() + 2);
    cuts.push(0);
    for s in splitters {
        cuts.push(rank_before_splitter(keys, pid, s));
    }
    cuts.push(keys.len());
    // Monotonicity is guaranteed when splitters are sorted; assert in
    // debug builds to catch mis-sorted splitter sets early.
    debug_assert!(cuts.windows(2).all(|w| w[0] <= w[1]), "non-monotone cuts");
    cuts
}

/// Plain lower bound (first index with `keys[i] >= x`).
pub fn lower_bound<T: Copy + Ord>(keys: &[T], x: T) -> usize {
    let mut lo = 0usize;
    let mut hi = keys.len();
    while lo < hi {
        let mid = lo + (hi - lo) / 2;
        if keys[mid] < x {
            lo = mid + 1;
        } else {
            hi = mid;
        }
    }
    lo
}

/// Plain upper bound (first index with `keys[i] > x`).
pub fn upper_bound<T: Copy + Ord>(keys: &[T], x: T) -> usize {
    let mut lo = 0usize;
    let mut hi = keys.len();
    while lo < hi {
        let mid = lo + (hi - lo) / 2;
        if keys[mid] <= x {
            lo = mid + 1;
        } else {
            hi = mid;
        }
    }
    lo
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::{arb_keys, check};

    #[test]
    fn bounds_basic() {
        let keys = [1, 3, 3, 5];
        assert_eq!(lower_bound(&keys, 3), 1);
        assert_eq!(upper_bound(&keys, 3), 3);
        assert_eq!(lower_bound(&keys, 0), 0);
        assert_eq!(upper_bound(&keys, 9), 4);
    }

    #[test]
    fn splitter_rank_tie_breaks_by_proc() {
        let keys = [7, 7, 7, 7];
        // Splitter key 7 owned by a *higher* processor: all local 7s on a
        // lower processor order before it.
        let s_hi = SampleRec::new(7, 5, 0);
        assert_eq!(rank_before_splitter(&keys, 2, &s_hi), 4);
        // Splitter owned by a lower processor: none go left.
        let s_lo = SampleRec::new(7, 0, 0);
        assert_eq!(rank_before_splitter(&keys, 2, &s_lo), 0);
    }

    #[test]
    fn splitter_rank_tie_breaks_by_index_on_same_proc() {
        let keys = [7, 7, 7, 7];
        let s = SampleRec::new(7, 2, 2);
        // Local keys at indices 0,1 are before (7, proc 2, idx 2).
        assert_eq!(rank_before_splitter(&keys, 2, &s), 2);
    }

    #[test]
    fn partition_points_are_monotone_property() {
        check("partition-points-monotone", |rng| {
            let mut keys = arb_keys(rng, 0, 500, -20, 20);
            keys.sort_unstable();
            let p = 1 + rng.below(8) as usize;
            let mut splitters: Vec<SampleRec> = (0..p - 1)
                .map(|_| {
                    SampleRec::new(
                        (rng.below(41) as i32) - 20,
                        rng.below(8) as usize,
                        rng.below(64) as usize,
                    )
                })
                .collect();
            splitters.sort();
            let cuts = partition_points(&keys, 3, &splitters);
            assert_eq!(cuts.len(), p + 1);
            assert!(cuts.windows(2).all(|w| w[0] <= w[1]));
            assert_eq!(cuts[0], 0);
            assert_eq!(*cuts.last().unwrap(), keys.len());
        });
    }

    #[test]
    fn rank_matches_linear_scan_property() {
        check("rank-vs-linear", |rng| {
            let mut keys = arb_keys(rng, 0, 300, -10, 10);
            keys.sort_unstable();
            let pid = rng.below(8) as usize;
            let s = SampleRec::new(
                (rng.below(21) as i32) - 10,
                rng.below(8) as usize,
                rng.below(512) as usize,
            );
            let linear = keys
                .iter()
                .enumerate()
                .take_while(|&(i, &k)| (k, pid as u32, i as u32) < (s.key, s.proc, s.idx))
                .count();
            assert_eq!(rank_before_splitter(&keys, pid, &s), linear);
        });
    }
}
