//! The paper's analytical performance model (DESIGN.md §4.7).
//!
//! Closed-form computation/communication times, π, µ and efficiency from
//! Proposition 5.1 (SORT_DET_BSP) and Proposition 5.3 (SORT_IRAN_BSP),
//! evaluated under concrete `(n, p, L, g)` — the §6.4 methodology: "based
//! on the theoretical performance of each algorithm under the BSP model
//! and the BSP parameters of a Cray T3D, it is possible to estimate the
//! actual performance of the implementations".

use crate::bsp::params::BspParams;
use crate::util::lg;

/// The analytic prediction for one algorithm at one configuration.
#[derive(Clone, Copy, Debug)]
pub struct Prediction {
    /// Computation time in comparisons (basic ops).
    pub comp_ops: f64,
    /// Communication time in µs (gh + L terms already priced).
    pub comm_us: f64,
    /// π = p·C_A / C_A* (computational efficiency ratio).
    pub pi: f64,
    /// µ = p·M_A / C_A* (communication impact ratio).
    pub mu: f64,
}

impl Prediction {
    /// Parallel efficiency 1/(π + µ) (§1.1).
    pub fn efficiency(&self) -> f64 {
        1.0 / (self.pi + self.mu)
    }

    /// Speedup p/(π + µ).
    pub fn speedup(&self, p: usize) -> f64 {
        p as f64 * self.efficiency()
    }

    /// Total predicted seconds on the machine.
    pub fn total_secs(&self, params: &BspParams) -> f64 {
        (params.comp_us(self.comp_ops) + self.comm_us) / 1e6
    }
}

/// The best sequential comparison sort charge: `n lg n` (§1.1).
pub fn seq_charge(n: usize) -> f64 {
    n as f64 * lg(n as f64)
}

/// Proposition 5.1 — SORT_DET_BSP with ω_n (⌈ω⌉ = r):
///
/// computation `(n/p)lg(n/p) + n_max·lg p + O(p + ω p lg²p)`,
/// communication `g·n_max + L·lg²p/2 + O(L + g·ω p lg²p)`,
/// `n_max = (1 + 1/⌈ω⌉)n/p + ⌈ω⌉p`.
pub fn predict_det(n: usize, params: &BspParams, omega: f64) -> Prediction {
    let p = params.p as f64;
    let nf = n as f64;
    let np = nf / p;
    let r = omega.ceil().max(1.0);
    let lgp = lg(p).max(1.0);
    let n_max = (1.0 + 1.0 / r) * np + r * p;

    // Computation: local sort + multiway merge + lower-order terms
    // (sample formation r·p, bitonic sample sort 2s(lg²p+lgp)/2 with
    // s = r·p, splitter search p·lg(n/p)).
    let s = r * p;
    let comp = np * lg(np)
        + n_max * lgp
        + s * (lgp * lgp + lgp)
        + p * lg(np).max(1.0)
        + p;

    // Communication: routing g·n_max, bitonic sample sort
    // (lg²p+lgp)/2 supersteps of (L + g·s), splitter broadcast, prefix.
    let bitonic_steps = (lgp * lgp + lgp) / 2.0;
    let comm_us = params.comm_us(n_max as u64)
        + bitonic_steps * (params.l_us + params.comm_us(s as u64 * 3))
        + 2.0 * params.l_us // splitter gather + broadcast
        + 2.0 * params.l_us; // prefix gather + scatter

    let c_seq = seq_charge(n);
    let pi = p * comp / c_seq;
    let mu = p * (comm_us * params.comps_per_us) / c_seq;
    Prediction { comp_ops: comp, comm_us, pi, mu }
}

/// Proposition 5.3 — SORT_IRAN_BSP with ω_n (s = 2ω²lg n):
///
/// computation `(n/p)lg(n/p) + (1+1/ω)(n/p)lg p + 2ω²lg n lg²p + O(...)`,
/// communication `(1+1/ω)g·n/p + g·ω²lg n·lg²p + L·lg²p/2 + O(...)`.
pub fn predict_iran(n: usize, params: &BspParams, omega: f64) -> Prediction {
    let p = params.p as f64;
    let nf = n as f64;
    let np = nf / p;
    let lgn = lg(nf).max(1.0);
    let lgp = lg(p).max(1.0);
    let w = omega.max(1.0);

    let s = 2.0 * w * w * lgn; // per-processor oversampling factor (§6.1)
    let comp = np * lg(np)
        + (1.0 + 1.0 / w) * np * lgp // multi-way merge of n_max keys
        + s * lgp * lgp // parallel (Batcher) sample sorting: 2ω²lg n·lg²p
        + p * lg(np).max(1.0);

    let bitonic_steps = (lgp * lgp + lgp) / 2.0;
    let comm_us = params.comm_us(((1.0 + 1.0 / w) * np) as u64)
        + bitonic_steps * (params.l_us + params.comm_us(s as u64 * 3))
        + 2.0 * params.l_us
        + 2.0 * params.l_us;

    let c_seq = seq_charge(n);
    let pi = p * comp / c_seq;
    let mu = p * (comm_us * params.comps_per_us) / c_seq;
    Prediction { comp_ops: comp, comm_us, pi, mu }
}

/// A multi-level prediction plus the topology that was *actually*
/// priced.
///
/// The per-level closed forms drop degenerate routing levels (factor
/// `k ≤ 1`, or a cell too small to split into `k` groups of ≥ 2); the
/// `effective` vector records what remains, so planners and report
/// tables can never describe a topology that wasn't priced.  The last
/// entry is always the leaf machine size; `effective == [p]` means the
/// whole request degraded to the one-level prediction.
#[derive(Clone, Debug)]
pub struct MultilevelPrediction {
    /// The combined closed-form prediction over all priced levels.
    pub prediction: Prediction,
    /// The factor vector actually priced (routing factors then leaf
    /// machine size).
    pub effective: Vec<usize>,
}

/// Shared per-routing-level + leaf composition for the multi-level
/// closed forms.  `factors` is the topology vector `[k1, …, kd]` (the
/// last entry is the leaf machine size; see
/// [`crate::bsp::group::Topology`]).  Routing level ℓ runs across the
/// current cell of `cell_p` processors under [`BspParams::scaled_to`]`
/// (cell_p)`; `route` prices one such level's computation given
/// `(np, k, cell_p)`.  The leaf is priced by `leaf`.
fn predict_topology_with(
    n: usize,
    params: &BspParams,
    factors: &[usize],
    route: impl Fn(f64, f64, f64) -> f64,
    leaf: impl Fn(usize, &BspParams) -> Prediction,
) -> MultilevelPrediction {
    let p = params.p as f64;
    let nf = n as f64;
    let np = nf / p;

    let mut effective: Vec<usize> = Vec::new();
    let mut cell_p = params.p;
    let mut n_leaf = n;
    let mut comp = 0.0f64;
    let mut comm_us = 0.0f64;
    // All entries but the last are routing levels; the last factor is
    // the leaf size, which is re-derived from the surviving cell width
    // (so a dropped level widens the leaf instead of orphaning keys).
    for &k in &factors[..factors.len().saturating_sub(1)] {
        if k <= 1 || cell_p < 2 * k {
            // Degenerate level: not priced, not recorded.
            continue;
        }
        let kf = k as f64;
        comp += route(np, kf, cell_p as f64);
        // One cell-wide route of ~n/p words per processor plus the
        // coarse gather + broadcast floors, under the cell-scaled L.
        let cell_params = params.scaled_to(cell_p);
        comm_us += cell_params.comm_us(np as u64) + 2.0 * cell_params.l_us;
        effective.push(k);
        cell_p /= k;
        n_leaf /= k;
    }

    // Leaf: the one-level algorithm inside the finest surviving cells.
    let lvl = leaf(n_leaf, &params.scaled_to(cell_p));
    effective.push(cell_p);
    comp += lvl.comp_ops;
    comm_us += lvl.comm_us;

    let c_seq = seq_charge(n);
    let pi = p * comp / c_seq;
    let mu = p * (comm_us * params.comps_per_us) / c_seq;
    MultilevelPrediction {
        prediction: Prediction { comp_ops: comp, comm_us, pi, mu },
        effective,
    }
}

/// Arbitrary-depth composition of Proposition 5.1 for the deterministic
/// multi-level sort (`sort::multilevel::sort_deep_det`) over the
/// topology vector `factors = [k1, …, kd]`:
///
/// * **each routing level ℓ** pays one local sort `(n/p)lg(n/p)` (the
///   received ranges of the previous level arrive concatenated, not
///   merged), a coarse sample of `r·k_ℓ` per processor sorted
///   sequentially at the cell leader (`s_ℓ lg s_ℓ` with
///   `s_ℓ = r·k_ℓ·cell_p`), the `(k_ℓ−1)`-way partition, a linear
///   concatenation term, and one cell-wide routing superstep of `~n/p`
///   words per processor plus the gather/broadcast L floors — all under
///   the cell-scaled parameters ([`BspParams::scaled_to`]);
/// * **the leaf** is the one-level prediction on the `kd`-processor
///   machine with `n/(k1…k_{d−1})` keys — smaller effective L, and
///   `lg²(kd)` instead of `lg²p` synchronization-bound supersteps.
///
/// The trade the recursion makes explicit: each extra `g·n/p` routing
/// pass buys synchronization and sample-sort terms that scale with the
/// cell size instead of the machine size.  Degenerate levels are
/// dropped and the priced topology is returned in
/// [`MultilevelPrediction::effective`].
pub fn predict_det_topology(
    n: usize,
    params: &BspParams,
    omega: f64,
    factors: &[usize],
) -> MultilevelPrediction {
    let r = omega.ceil().max(1.0);
    predict_topology_with(
        n,
        params,
        factors,
        |np, kf, cell_p| {
            let s = r * kf * cell_p; // gathered coarse sample at the cell leader
            np * lg(np) + s * lg(s).max(1.0) + (kf - 1.0) * lg(np).max(1.0) + np
        },
        |n_leaf, leaf_params| predict_det(n_leaf, leaf_params, omega),
    )
}

/// The randomized twin of [`predict_det_topology`]
/// (`sort::multilevel::sort_deep_ran`): each routing level randomly
/// samples `share = 2ω²lg n` keys per processor (no local sort — the
/// randomized variant routes unsorted keys), sorts the gathered sample
/// at the cell leader, then pays the per-key set formation
/// `(n/p)(lg k_ℓ + 3)`; the leaf is [`predict_iran`], the closest
/// closed form to the leaf's SORT_RAN_BSP.
pub fn predict_ran_topology(
    n: usize,
    params: &BspParams,
    omega: f64,
    factors: &[usize],
) -> MultilevelPrediction {
    let w = omega.max(1.0);
    let share = 2.0 * w * w * lg(n as f64).max(1.0);
    predict_topology_with(
        n,
        params,
        factors,
        |np, kf, cell_p| {
            let s = share * cell_p; // gathered sample at the cell leader
            share + s * lg(s).max(1.0) + np * (lg(kf).max(1.0) + 3.0) + np
        },
        |n_leaf, leaf_params| predict_iran(n_leaf, leaf_params, omega),
    )
}

/// Two-level composition of Proposition 5.1 for the k-group multi-level
/// deterministic sort — [`predict_det_topology`] over `[k, p/k]`, kept
/// as the historical det2 pricing entry point.
///
/// When `k ≤ 1` or `p < 2k` the level degrades and the one-level
/// prediction is returned, with the degradation *observable*:
/// [`MultilevelPrediction::effective`] is `[p]` instead of `[k, p/k]`.
pub fn predict_det_multilevel(
    n: usize,
    params: &BspParams,
    omega: f64,
    k: usize,
) -> MultilevelPrediction {
    let k = k.max(1);
    predict_det_topology(n, params, omega, &[k, params.p.div_ceil(k)])
}

/// The EM-BSP prediction for one external sort: the usual BSP terms
/// plus the block-I/O bill, kept separate so reports can show the
/// `G_io·b` share on its own.
#[derive(Clone, Copy, Debug)]
pub struct ExternalPrediction {
    /// The BSP computation/communication prediction.
    pub prediction: Prediction,
    /// Predicted block transfers on the busiest processor (run-
    /// formation writes + merge reads).
    pub io_blocks: u64,
    /// Those transfers priced at `G_io` ([`BspParams::io_us`]), µs.
    pub io_us: f64,
}

impl ExternalPrediction {
    /// Total predicted seconds including the I/O term.
    pub fn total_secs(&self, params: &BspParams) -> f64 {
        self.prediction.total_secs(params) + self.io_us / 1e6
    }
}

/// Closed form for the out-of-core sort ([`crate::ext::sort_external`])
/// under EM-BSP `(p, L, g, G_io)` — the same "predict, then compare to
/// the measured ledger" methodology the in-core forms follow (§6.4).
///
/// With `n_p = n/p` keys per processor, memory budget `M` keys, and
/// `R_p = ⌈n_p/M⌉` runs per processor:
///
/// * **run formation** — `R_p` chunk sorts totalling `n_p·lg(min(M,
///   n_p))`, one encode pass `n_p`, and `⌈m·w/B⌉` block writes per
///   run (`w` wire words per key, `B` block words);
/// * **merge** — read the same blocks back (decode pass `n_p`),
///   partition each run at `p−1` splitters (`R_p(p−1)·⌈lg M⌉`), one
///   `g·n_p·w` routing superstep, and an `R`-way loser-tree merge
///   ([`crate::seq::ops::merge_charge`] at fan-in `R = p·R_p`, the
///   worst-case segment count);
/// * **I/O** — `2·blocks_p` transfers at `G_io` each.
///
/// Like Props 5.1/5.3 this is an upper-bound shape, not an exact
/// replay: the conformance gate is the ledger comparison, and this
/// form tracks how the bill scales with `(n, p, M, G_io)`.
pub fn predict_external(
    n: usize,
    params: &BspParams,
    mem_budget: usize,
    key_words: u64,
) -> ExternalPrediction {
    let p = params.p as f64;
    let np = (n as f64 / p).max(1.0);
    let n_local = (n / params.p.max(1)).max(1);
    let m = mem_budget.max(1).min(n_local);
    let runs_per_proc = n_local.div_ceil(m);
    let total_runs = (runs_per_proc * params.p).max(1);

    // Computation: chunk sorts + encode, decode, partition, merge.
    let w = key_words.max(1) as usize;
    let block = crate::ext::DEFAULT_BLOCK_WORDS;
    let comp = np * lg(m as f64).max(1.0)
        + 2.0 * np
        + runs_per_proc as f64 * (p - 1.0) * lg(m as f64).max(1.0).ceil()
        + crate::seq::ops::merge_charge(n_local, total_runs);

    // Communication: the one scatter h-relation plus the three
    // superstep floors (read, scatter, merge barriers).
    let comm_us = params.comm_us((n_local * w) as u64) + 3.0 * params.l_us;

    // I/O: every run's blocks written once and read once.
    let full_runs = runs_per_proc.saturating_sub(1);
    let tail = n_local - full_runs * m;
    let blocks_per_proc = (full_runs * (m * w).div_ceil(block) + (tail * w).div_ceil(block)) as u64;
    let io_blocks = 2 * blocks_per_proc;

    let c_seq = seq_charge(n);
    let pi = p * comp / c_seq;
    let mu = p * (comm_us * params.comps_per_us) / c_seq;
    ExternalPrediction {
        prediction: Prediction { comp_ops: comp, comm_us, pi, mu },
        io_blocks,
        io_us: params.io_us(io_blocks),
    }
}

/// Validity ranges: the conditions of Props 5.1/5.3.
pub fn det_conditions_hold(n: usize, p: usize, omega: f64) -> bool {
    // p²ω² ≤ n / lg n and ω = O(lg n).
    let nf = n as f64;
    (p * p) as f64 * omega * omega <= nf / lg(nf).max(1.0) && omega <= lg(nf)
}

pub fn iran_conditions_hold(n: usize, p: usize, omega: f64) -> bool {
    // 2pω²lg n < n/2 and p² ≤ n/(ω lg n).
    let nf = n as f64;
    let lgn = lg(nf).max(1.0);
    2.0 * p as f64 * omega * omega * lgn < nf / 2.0
        && (p * p) as f64 <= nf / (omega * lgn)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bsp::params::cray_t3d;

    /// §6.4: for n = 8M, p = 128 the theory predicts ≥ 66 % efficiency
    /// for \[DSQ\] (low-order terms ignored).  Our closed form keeps some
    /// low-order terms, so allow the band 55–80 %.
    #[test]
    fn det_efficiency_near_paper_estimate() {
        let n = 1usize << 23;
        let params = cray_t3d(128);
        let omega = lg(n as f64).log2(); // lg lg n
        let pred = predict_det(n, &params, omega);
        let eff = pred.efficiency();
        assert!((0.5..0.85).contains(&eff), "eff={eff}");
    }

    /// §6.4: the theory gives both algorithms an efficiency bound of
    /// "at least 66 %" at n = 8M, p = 128.  (The *observed* advantage of
    /// the randomized variant comes from tighter realized imbalance, not
    /// from the upper-bound formulas — see tables::validate::predict.)
    #[test]
    fn both_predictions_in_paper_band_at_scale() {
        let n = 1usize << 23;
        let params = cray_t3d(128);
        let det = predict_det(n, &params, lg(n as f64).log2());
        let iran = predict_iran(n, &params, lg(n as f64).sqrt());
        for (name, eff) in [("det", det.efficiency()), ("iran", iran.efficiency())] {
            assert!((0.5..0.9).contains(&eff), "{name} eff={eff}");
        }
    }

    #[test]
    fn efficiency_improves_with_fewer_procs() {
        let n = 1usize << 23;
        let e16 = predict_det(n, &cray_t3d(16), 4.5).efficiency();
        let e128 = predict_det(n, &cray_t3d(128), 4.5).efficiency();
        assert!(e16 > e128, "e16={e16} e128={e128}");
    }

    #[test]
    fn pi_exceeds_one_and_shrinks_with_n() {
        let params = cray_t3d(64);
        let p1 = predict_det(1 << 20, &params, 4.0);
        let p2 = predict_det(1 << 26, &params, 4.0);
        assert!(p1.pi > 1.0 && p2.pi > 1.0);
        assert!(p2.pi < p1.pi, "π must shrink as n grows (one-optimality)");
    }

    #[test]
    fn multilevel_cuts_communication_at_scale() {
        // At n = 8M, p = 128 the two-level recursion (8×16) trades one
        // extra g·n/p routing pass for group-local synchronization and
        // sample-sort terms — a net communication win.
        let n = 1usize << 23;
        let params = cray_t3d(128);
        let omega = lg(n as f64).log2();
        let one = predict_det(n, &params, omega);
        let two = predict_det_multilevel(n, &params, omega, 8);
        assert_eq!(two.effective, vec![8, 16]);
        assert!(
            two.prediction.comm_us < one.comm_us,
            "two-level comm {} must beat one-level {}",
            two.prediction.comm_us,
            one.comm_us
        );
        let eff = two.prediction.efficiency();
        assert!(eff > 0.0 && eff < 1.0);
        // k = 1 degrades to the one-level prediction exactly.
        let k1 = predict_det_multilevel(n, &params, omega, 1);
        assert_eq!(k1.prediction.comm_us, one.comm_us);
        assert_eq!(k1.prediction.comp_ops, one.comp_ops);
        assert_eq!(k1.effective, vec![128]);
    }

    /// Regression for the silent `p < 2k` fallback: the one-level
    /// prediction is still returned, but the degradation is observable
    /// through `effective` — a caller can no longer describe the run as
    /// "k groups" when no grouping was priced.
    #[test]
    fn degraded_multilevel_records_effective_topology() {
        let n = 1usize << 20;
        let params = cray_t3d(16);
        let omega = 4.0;
        let one = predict_det(n, &params, omega);
        // k = 12 needs p ≥ 24; at p = 16 the level must degrade.
        let deg = predict_det_multilevel(n, &params, omega, 12);
        assert_eq!(deg.prediction.comm_us, one.comm_us);
        assert_eq!(deg.prediction.comp_ops, one.comp_ops);
        assert_eq!(deg.effective, vec![16], "degraded topology must be observable");
        // A healthy k stays fully priced and observable.
        let ok = predict_det_multilevel(n, &params, omega, 4);
        assert_eq!(ok.effective, vec![4, 4]);
        assert!(ok.prediction.comp_ops != one.comp_ops);
    }

    #[test]
    fn topology_predictions_drop_degenerate_levels() {
        let n = 1usize << 23;
        let params = cray_t3d(64);
        let omega = lg(n as f64).log2();
        // [1, 8, 8]: the k=1 level prices nothing; effective is [8, 8].
        let d = predict_det_topology(n, &params, omega, &[1, 8, 8]);
        assert_eq!(d.effective, vec![8, 8]);
        let clean = predict_det_topology(n, &params, omega, &[8, 8]);
        assert_eq!(d.prediction.comp_ops, clean.prediction.comp_ops);
        assert_eq!(d.prediction.comm_us, clean.prediction.comm_us);
        // Depth 3 prices three levels and keeps a sane efficiency.
        let d3 = predict_det_topology(n, &params, omega, &[4, 4, 4]);
        assert_eq!(d3.effective, vec![4, 4, 4]);
        let eff = d3.prediction.efficiency();
        assert!(eff > 0.0 && eff < 1.0, "eff={eff}");
        // The randomized twin prices the same shapes.
        let r3 = predict_ran_topology(n, &params, lg(n as f64).sqrt(), &[4, 4, 4]);
        assert_eq!(r3.effective, vec![4, 4, 4]);
        assert!(r3.prediction.comm_us > 0.0 && r3.prediction.comp_ops > 0.0);
    }

    #[test]
    fn external_prediction_prices_the_io_term() {
        use crate::bsp::params::T3D_IO_US_PER_BLOCK;
        let n = 1usize << 20;
        let flat = cray_t3d(16);
        let em = flat.with_io(T3D_IO_US_PER_BLOCK);
        let pred = predict_external(n, &em, 1 << 12, 1);
        assert!(pred.io_blocks > 0);
        assert!((pred.io_us - pred.io_blocks as f64 * T3D_IO_US_PER_BLOCK).abs() < 1e-6);
        // Without G_io the same shape prices its transfers at zero.
        let free = predict_external(n, &flat, 1 << 12, 1);
        assert_eq!(free.io_blocks, pred.io_blocks);
        assert_eq!(free.io_us, 0.0);
        assert!(pred.total_secs(&em) > free.total_secs(&flat));
    }

    #[test]
    fn tighter_budgets_cost_more_merge_and_never_less_io() {
        let em = cray_t3d(16).with_io(327.0);
        let n = 1usize << 20;
        let tight = predict_external(n, &em, 1 << 10, 1);
        let loose = predict_external(n, &em, 1 << 14, 1);
        assert!(
            tight.prediction.comp_ops > loose.prediction.comp_ops,
            "more runs ⇒ a wider merge fan-in"
        );
        assert!(tight.io_blocks >= loose.io_blocks, "per-run rounding only adds blocks");
        // Two-word keys double the block count (±rounding).
        let wide = predict_external(n, &em, 1 << 14, 2);
        assert!(wide.io_blocks >= 2 * loose.io_blocks - 2);
    }

    #[test]
    fn condition_ranges() {
        assert!(det_conditions_hold(1 << 23, 16, 4.5));
        assert!(!det_conditions_hold(1 << 10, 128, 4.5));
        assert!(iran_conditions_hold(1 << 23, 16, 4.8));
        assert!(!iran_conditions_hold(1 << 12, 128, 4.8));
    }

    #[test]
    fn total_secs_scale_with_n() {
        let params = cray_t3d(64);
        let a = predict_det(1 << 20, &params, 4.5).total_secs(&params);
        let b = predict_det(1 << 23, &params, 4.5).total_secs(&params);
        assert!(b > 7.0 * a && b < 10.0 * a, "a={a} b={b}");
    }
}
