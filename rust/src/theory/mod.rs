//! The paper's analytical performance model (DESIGN.md §4.7).
//!
//! Closed-form computation/communication times, π, µ and efficiency from
//! Proposition 5.1 (SORT_DET_BSP) and Proposition 5.3 (SORT_IRAN_BSP),
//! evaluated under concrete `(n, p, L, g)` — the §6.4 methodology: "based
//! on the theoretical performance of each algorithm under the BSP model
//! and the BSP parameters of a Cray T3D, it is possible to estimate the
//! actual performance of the implementations".

use crate::bsp::params::BspParams;
use crate::util::lg;

/// The analytic prediction for one algorithm at one configuration.
#[derive(Clone, Copy, Debug)]
pub struct Prediction {
    /// Computation time in comparisons (basic ops).
    pub comp_ops: f64,
    /// Communication time in µs (gh + L terms already priced).
    pub comm_us: f64,
    /// π = p·C_A / C_A* (computational efficiency ratio).
    pub pi: f64,
    /// µ = p·M_A / C_A* (communication impact ratio).
    pub mu: f64,
}

impl Prediction {
    /// Parallel efficiency 1/(π + µ) (§1.1).
    pub fn efficiency(&self) -> f64 {
        1.0 / (self.pi + self.mu)
    }

    /// Speedup p/(π + µ).
    pub fn speedup(&self, p: usize) -> f64 {
        p as f64 * self.efficiency()
    }

    /// Total predicted seconds on the machine.
    pub fn total_secs(&self, params: &BspParams) -> f64 {
        (params.comp_us(self.comp_ops) + self.comm_us) / 1e6
    }
}

/// The best sequential comparison sort charge: `n lg n` (§1.1).
pub fn seq_charge(n: usize) -> f64 {
    n as f64 * lg(n as f64)
}

/// Proposition 5.1 — SORT_DET_BSP with ω_n (⌈ω⌉ = r):
///
/// computation `(n/p)lg(n/p) + n_max·lg p + O(p + ω p lg²p)`,
/// communication `g·n_max + L·lg²p/2 + O(L + g·ω p lg²p)`,
/// `n_max = (1 + 1/⌈ω⌉)n/p + ⌈ω⌉p`.
pub fn predict_det(n: usize, params: &BspParams, omega: f64) -> Prediction {
    let p = params.p as f64;
    let nf = n as f64;
    let np = nf / p;
    let r = omega.ceil().max(1.0);
    let lgp = lg(p).max(1.0);
    let n_max = (1.0 + 1.0 / r) * np + r * p;

    // Computation: local sort + multiway merge + lower-order terms
    // (sample formation r·p, bitonic sample sort 2s(lg²p+lgp)/2 with
    // s = r·p, splitter search p·lg(n/p)).
    let s = r * p;
    let comp = np * lg(np)
        + n_max * lgp
        + s * (lgp * lgp + lgp)
        + p * lg(np).max(1.0)
        + p;

    // Communication: routing g·n_max, bitonic sample sort
    // (lg²p+lgp)/2 supersteps of (L + g·s), splitter broadcast, prefix.
    let bitonic_steps = (lgp * lgp + lgp) / 2.0;
    let comm_us = params.comm_us(n_max as u64)
        + bitonic_steps * (params.l_us + params.comm_us(s as u64 * 3))
        + 2.0 * params.l_us // splitter gather + broadcast
        + 2.0 * params.l_us; // prefix gather + scatter

    let c_seq = seq_charge(n);
    let pi = p * comp / c_seq;
    let mu = p * (comm_us * params.comps_per_us) / c_seq;
    Prediction { comp_ops: comp, comm_us, pi, mu }
}

/// Proposition 5.3 — SORT_IRAN_BSP with ω_n (s = 2ω²lg n):
///
/// computation `(n/p)lg(n/p) + (1+1/ω)(n/p)lg p + 2ω²lg n lg²p + O(...)`,
/// communication `(1+1/ω)g·n/p + g·ω²lg n·lg²p + L·lg²p/2 + O(...)`.
pub fn predict_iran(n: usize, params: &BspParams, omega: f64) -> Prediction {
    let p = params.p as f64;
    let nf = n as f64;
    let np = nf / p;
    let lgn = lg(nf).max(1.0);
    let lgp = lg(p).max(1.0);
    let w = omega.max(1.0);

    let s = 2.0 * w * w * lgn; // per-processor oversampling factor (§6.1)
    let comp = np * lg(np)
        + (1.0 + 1.0 / w) * np * lgp // multi-way merge of n_max keys
        + s * lgp * lgp // parallel (Batcher) sample sorting: 2ω²lg n·lg²p
        + p * lg(np).max(1.0);

    let bitonic_steps = (lgp * lgp + lgp) / 2.0;
    let comm_us = params.comm_us(((1.0 + 1.0 / w) * np) as u64)
        + bitonic_steps * (params.l_us + params.comm_us(s as u64 * 3))
        + 2.0 * params.l_us
        + 2.0 * params.l_us;

    let c_seq = seq_charge(n);
    let pi = p * comp / c_seq;
    let mu = p * (comm_us * params.comps_per_us) / c_seq;
    Prediction { comp_ops: comp, comm_us, pi, mu }
}

/// Two-level composition of Proposition 5.1 for the k-group multi-level
/// deterministic sort (`sort::multilevel`):
///
/// * **level 1** pays one local sort `(n/p)lg(n/p)`, a coarse sample of
///   `r·k` per processor sorted sequentially at processor 0
///   (`r·k·p·lg(r·k·p)`), the `(k−1)`-way partition, a linear
///   concatenation of the received ranges (the implementation
///   deliberately does *not* merge at level 1 — level 2's own local
///   sort subsumes it), and one whole-machine routing superstep of
///   `~n/p` words per processor plus the gather/broadcast L floors;
/// * **level 2** is the one-level prediction on the `(p/k)`-processor
///   group machine with `n/k` keys, priced under the group-scaled
///   parameters ([`BspParams::scaled_to`]) — smaller effective L, and
///   `lg²(p/k)` instead of `lg²p` synchronization-bound supersteps.
///
/// The trade the recursion makes explicit: one extra `g·n/p` routing
/// pass buys synchronization and sample-sort terms that scale with the
/// group size instead of the machine size.
pub fn predict_det_multilevel(
    n: usize,
    params: &BspParams,
    omega: f64,
    k: usize,
) -> Prediction {
    let k = k.max(1);
    if k == 1 || params.p < 2 * k {
        return predict_det(n, params, omega);
    }
    let p = params.p as f64;
    let nf = n as f64;
    let np = nf / p;
    let r = omega.ceil().max(1.0);
    let kf = k as f64;

    // Level-1 computation (per processor).  The received ranges are
    // concatenated, not merged (matching `sort_multilevel_det`): a
    // linear np term, since level 2 re-sorts regardless.
    let s1 = r * kf * p; // gathered coarse sample at processor 0
    let comp1 = np * lg(np)
        + s1 * lg(s1).max(1.0)
        + (kf - 1.0) * lg(np).max(1.0)
        + np; // concatenation of received ranges
    // Level-1 communication: one whole-machine route of ~n/p words per
    // processor plus the coarse gather + broadcast floors.
    let comm1_us = params.comm_us(np as u64) + 2.0 * params.l_us;

    // Level 2: the one-level algorithm, group-locally.
    let sub = params.scaled_to(params.p / k);
    let lvl2 = predict_det(n / k, &sub, omega);

    let comp = comp1 + lvl2.comp_ops;
    let comm_us = comm1_us + lvl2.comm_us;
    let c_seq = seq_charge(n);
    let pi = p * comp / c_seq;
    let mu = p * (comm_us * params.comps_per_us) / c_seq;
    Prediction { comp_ops: comp, comm_us, pi, mu }
}

/// Validity ranges: the conditions of Props 5.1/5.3.
pub fn det_conditions_hold(n: usize, p: usize, omega: f64) -> bool {
    // p²ω² ≤ n / lg n and ω = O(lg n).
    let nf = n as f64;
    (p * p) as f64 * omega * omega <= nf / lg(nf).max(1.0) && omega <= lg(nf)
}

pub fn iran_conditions_hold(n: usize, p: usize, omega: f64) -> bool {
    // 2pω²lg n < n/2 and p² ≤ n/(ω lg n).
    let nf = n as f64;
    let lgn = lg(nf).max(1.0);
    2.0 * p as f64 * omega * omega * lgn < nf / 2.0
        && (p * p) as f64 <= nf / (omega * lgn)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bsp::params::cray_t3d;

    /// §6.4: for n = 8M, p = 128 the theory predicts ≥ 66 % efficiency
    /// for \[DSQ\] (low-order terms ignored).  Our closed form keeps some
    /// low-order terms, so allow the band 55–80 %.
    #[test]
    fn det_efficiency_near_paper_estimate() {
        let n = 1usize << 23;
        let params = cray_t3d(128);
        let omega = lg(n as f64).log2(); // lg lg n
        let pred = predict_det(n, &params, omega);
        let eff = pred.efficiency();
        assert!((0.5..0.85).contains(&eff), "eff={eff}");
    }

    /// §6.4: the theory gives both algorithms an efficiency bound of
    /// "at least 66 %" at n = 8M, p = 128.  (The *observed* advantage of
    /// the randomized variant comes from tighter realized imbalance, not
    /// from the upper-bound formulas — see tables::validate::predict.)
    #[test]
    fn both_predictions_in_paper_band_at_scale() {
        let n = 1usize << 23;
        let params = cray_t3d(128);
        let det = predict_det(n, &params, lg(n as f64).log2());
        let iran = predict_iran(n, &params, lg(n as f64).sqrt());
        for (name, eff) in [("det", det.efficiency()), ("iran", iran.efficiency())] {
            assert!((0.5..0.9).contains(&eff), "{name} eff={eff}");
        }
    }

    #[test]
    fn efficiency_improves_with_fewer_procs() {
        let n = 1usize << 23;
        let e16 = predict_det(n, &cray_t3d(16), 4.5).efficiency();
        let e128 = predict_det(n, &cray_t3d(128), 4.5).efficiency();
        assert!(e16 > e128, "e16={e16} e128={e128}");
    }

    #[test]
    fn pi_exceeds_one_and_shrinks_with_n() {
        let params = cray_t3d(64);
        let p1 = predict_det(1 << 20, &params, 4.0);
        let p2 = predict_det(1 << 26, &params, 4.0);
        assert!(p1.pi > 1.0 && p2.pi > 1.0);
        assert!(p2.pi < p1.pi, "π must shrink as n grows (one-optimality)");
    }

    #[test]
    fn multilevel_cuts_communication_at_scale() {
        // At n = 8M, p = 128 the two-level recursion (8×16) trades one
        // extra g·n/p routing pass for group-local synchronization and
        // sample-sort terms — a net communication win.
        let n = 1usize << 23;
        let params = cray_t3d(128);
        let omega = lg(n as f64).log2();
        let one = predict_det(n, &params, omega);
        let two = predict_det_multilevel(n, &params, omega, 8);
        assert!(
            two.comm_us < one.comm_us,
            "two-level comm {} must beat one-level {}",
            two.comm_us,
            one.comm_us
        );
        assert!(two.efficiency() > 0.0 && two.efficiency() < 1.0);
        // k = 1 degrades to the one-level prediction exactly.
        let k1 = predict_det_multilevel(n, &params, omega, 1);
        assert_eq!(k1.comm_us, one.comm_us);
        assert_eq!(k1.comp_ops, one.comp_ops);
    }

    #[test]
    fn condition_ranges() {
        assert!(det_conditions_hold(1 << 23, 16, 4.5));
        assert!(!det_conditions_hold(1 << 10, 128, 4.5));
        assert!(iran_conditions_hold(1 << 23, 16, 4.8));
        assert!(!iran_conditions_hold(1 << 12, 128, 4.8));
    }

    #[test]
    fn total_secs_scale_with_n() {
        let params = cray_t3d(64);
        let a = predict_det(1 << 20, &params, 4.5).total_secs(&params);
        let b = predict_det(1 << 23, &params, 4.5).total_secs(&params);
        assert!(b > 7.0 * a && b < 10.0 * a, "a={a} b={b}");
    }
}
